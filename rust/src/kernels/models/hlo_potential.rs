//! The machine-learned potential as a PAL model kernel, backed by the AOT
//! artifacts (`potential_<tag>_{fwd,euq,train,init}`).
//!
//! One instance = one committee member (one prediction or training rank).
//! Wire formats (shared with [`crate::kernels::generators::MdGenerator`]
//! and [`crate::kernels::oracles::PesOracle`]):
//!
//! * `data_to_pred` row = `[x (N*3), g (G), s (S)]`
//! * prediction row     = `[e (S), f (N*3)]` (this member's energies +
//!   state-weighted forces)
//! * datapoint          = `(input_row, [e (S), f (N*3)])`

use std::collections::BTreeMap;

use anyhow::Context;

use crate::comm::bus::Payload;
use crate::data::batch::{Batch, BatchView, DatapointView, RowBlock};
use crate::data::Dataset;
use crate::kernels::{Mode, Model};
use crate::runtime::{Engine, Manifest, TensorIn};

use super::util::{pad_rows, plan_chunks, split_columns, ColumnScratch};

/// Tunables for the training side.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    /// Adam steps per retraining round (between interrupt checks the cost
    /// is one HLO call, so interrupts are honored at step granularity).
    pub epochs_per_round: usize,
    /// Validation fraction of incoming labeled data.
    pub val_split: f64,
    /// Rolling-window cap on the training set (SI use case 2), if any.
    pub rolling_window: Option<usize>,
    /// Ask the controller to stop the workflow once training loss falls
    /// below this (end-to-end convergence criterion).
    pub stop_below_loss: Option<f32>,
    /// Checkpoint file for `save_progress` (weights + optimizer + dataset);
    /// loaded back on construction when it exists (the paper's `result_dir`
    /// persistence, SI §S5).
    pub checkpoint: Option<std::path::PathBuf>,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            epochs_per_round: 32,
            val_split: 0.15,
            rolling_window: None,
            stop_below_loss: None,
            checkpoint: None,
        }
    }
}

/// One committee member of the ML potential, serving either kernel side.
pub struct HloPotentialModel {
    engine: Engine,
    mode: Mode,
    // manifest-derived shapes
    n_atoms: usize,
    n_globals: usize,
    n_states: usize,
    param_size: usize,
    opt_size: usize,
    fwd_names: BTreeMap<usize, String>,
    train_name: String,
    train_batch: usize,
    // state
    w: Vec<f32>,
    /// Weights adopted from a shared wire payload (`update_from`): the
    /// prediction replica reads through the trainer's buffer (refcount
    /// bump, zero copies). Cleared whenever `w` is written locally.
    w_shared: Option<Payload>,
    opt: Vec<f32>,
    dataset: Dataset,
    last_loss: Option<f32>,
    last_round_epochs: u64,
    opts: TrainOptions,
    rounds: u64,
    /// Persistent column-split scratches (cleared, not reallocated, per
    /// call): input columns for forward/train staging, label columns for
    /// the train step — the HLO hot paths are allocation-free in steady
    /// state.
    in_scratch: ColumnScratch,
    lab_scratch: ColumnScratch,
}

impl HloPotentialModel {
    /// Build a member model from the artifact set `potential_<tag>_*`.
    /// `seed` individualizes the member (pass `base_seed + replica`).
    pub fn new(
        manifest: Manifest,
        tag: &str,
        mode: Mode,
        seed: u32,
        opts: TrainOptions,
    ) -> anyhow::Result<Self> {
        let engine = Engine::new(manifest)?;
        let init_name = format!("potential_{tag}_init");
        let init = engine.entry(&init_name)?;
        anyhow::ensure!(
            init.meta_usize("n_members")? == 1,
            "HloPotentialModel needs a single-member artifact set (tag {tag} has n_members={})",
            init.meta_usize("n_members")?
        );
        let n_atoms = init.meta_usize("n_atoms")?;
        let n_globals = init.meta_usize("n_globals")?;
        let n_states = init.meta_usize("n_states")?;
        let param_size = init.meta_usize("param_size")?;
        let opt_size = init.meta_usize("opt_size")?;

        let mut fwd_names = BTreeMap::new();
        let mut train_name = None;
        let mut train_batch = 0;
        for e in engine.manifest().with_prefix(&format!("potential_{tag}_")) {
            match e.meta.get("entry").as_str() {
                Some("fwd") => {
                    fwd_names.insert(e.meta_usize("batch")?, e.name.clone());
                }
                Some("train") => {
                    train_batch = e.meta_usize("batch")?;
                    train_name = Some(e.name.clone());
                }
                _ => {}
            }
        }
        let train_name = train_name.context("no train artifact for tag")?;
        anyhow::ensure!(!fwd_names.is_empty(), "no fwd artifacts for tag {tag}");

        // member init on-device (same HLO the paper's training kernel owns)
        let w = engine
            .call(&init_name, &[TensorIn::U32(seed)])?
            .remove(0);
        debug_assert_eq!(w.len(), param_size);

        let mut model = HloPotentialModel {
            engine,
            mode,
            n_atoms,
            n_globals,
            n_states,
            param_size,
            opt_size,
            fwd_names,
            train_name,
            train_batch,
            w,
            w_shared: None,
            opt: vec![0.0; opt_size],
            dataset: {
                let d = Dataset::new(opts.val_split, seed as u64 ^ 0xDA7A);
                match opts.rolling_window {
                    Some(cap) => d.with_rolling_window(cap),
                    None => d,
                }
            },
            last_loss: None,
            last_round_epochs: 0,
            opts,
            rounds: 0,
            in_scratch: ColumnScratch::new(),
            lab_scratch: ColumnScratch::new(),
        };
        model.try_load_checkpoint();
        Ok(model)
    }

    /// Restore weights/optimizer/dataset from the checkpoint, if present.
    fn try_load_checkpoint(&mut self) {
        let Some(path) = self.opts.checkpoint.clone() else { return };
        let Ok(text) = std::fs::read_to_string(&path) else { return };
        let Ok(v) = crate::json::parse(&text) else { return };
        let read_vec = |val: &crate::json::Value| -> Vec<f32> {
            val.as_array()
                .map(|a| a.iter().filter_map(|x| x.as_f64()).map(|f| f as f32).collect())
                .unwrap_or_default()
        };
        let w = read_vec(v.get("w"));
        let opt = read_vec(v.get("opt"));
        if w.len() == self.param_size && opt.len() == self.opt_size {
            self.w = w;
            self.w_shared = None;
            self.opt = opt;
        }
        if let Some(rounds) = v.get("rounds").as_f64() {
            self.rounds = rounds as u64;
        }
        if let (Some(xs), Some(ys)) = (v.get("xs").as_array(), v.get("ys").as_array()) {
            let points: Vec<(Vec<f32>, Vec<f32>)> = xs
                .iter()
                .zip(ys)
                .map(|(x, y)| (read_vec(x), read_vec(y)))
                .filter(|(x, y)| {
                    x.len() == self.input_row_len() && y.len() == self.label_row_len()
                })
                .collect();
            self.dataset.add(&points);
        }
        if let Some(loss) = v.get("last_loss").as_f64() {
            self.last_loss = Some(loss as f32);
        }
    }

    fn write_checkpoint(&self) {
        let Some(path) = &self.opts.checkpoint else { return };
        if let Some(parent) = path.parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        use crate::json::{arr_f32, obj, Value};
        let xs = Value::Array(self.dataset.train_inputs().map(arr_f32).collect());
        let ys = Value::Array(self.dataset.train_labels().map(arr_f32).collect());
        let snap = obj(vec![
            ("w", arr_f32(self.weights_slice())),
            ("opt", arr_f32(&self.opt)),
            ("rounds", Value::Num(self.rounds as f64)),
            ("last_loss", match self.last_loss {
                Some(l) if l.is_finite() => Value::Num(l as f64),
                _ => Value::Null,
            }),
            ("xs", xs),
            ("ys", ys),
        ]);
        let _ = std::fs::write(path, crate::json::to_string(&snap));
    }

    pub fn input_row_len(&self) -> usize {
        self.n_atoms * 3 + self.n_globals + self.n_states
    }

    pub fn output_row_len(&self) -> usize {
        self.n_states + self.n_atoms * 3
    }

    pub fn label_row_len(&self) -> usize {
        self.n_states + self.n_atoms * 3
    }

    pub fn mode(&self) -> Mode {
        self.mode
    }

    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    pub fn n_train(&self) -> usize {
        self.dataset.n_train()
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Active weights: the adopted shared payload when one is held, the
    /// owned buffer otherwise.
    fn weights_slice(&self) -> &[f32] {
        match &self.w_shared {
            Some(p) => p.as_slice(),
            None => &self.w,
        }
    }

    /// Active weights as an engine input. An adopted shared payload goes in
    /// as [`TensorIn::Shared`], so repeat calls between weight syncs hit the
    /// engine's upload cache instead of re-staging `param_size` floats.
    fn weights_in(&self) -> TensorIn<'_> {
        match &self.w_shared {
            Some(p) => TensorIn::Shared(p),
            None => TensorIn::F32(&self.w),
        }
    }

    fn widths(&self) -> [usize; 3] {
        [self.n_atoms * 3, self.n_globals, self.n_states]
    }

    /// Forward one column-split chunk (`used` live rows in `cols`): pads
    /// each column block to the artifact batch, runs the forward, and
    /// extracts the `(e_mean, f_mean)` output tensors — the single place
    /// both the nested and flat predict paths get the output layout from.
    /// `cols` may be the persistent [`ColumnScratch`] buffers; padding
    /// mutates them in place.
    fn fwd_cols(
        &self,
        batch: usize,
        used: usize,
        cols: &mut [Vec<f32>],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let name = &self.fwd_names[&batch];
        let [n3, g, s] = self.widths();
        pad_rows(&mut cols[0], used, batch, n3);
        pad_rows(&mut cols[1], used, batch, g);
        pad_rows(&mut cols[2], used, batch, s);
        let out = self.engine.call(
            name,
            &[
                self.weights_in(),
                TensorIn::F32(&cols[0]),
                TensorIn::F32(&cols[1]),
                TensorIn::F32(&cols[2]),
            ],
        )?;
        // outputs: e_all(M=1,B,S), e_mean(B,S), e_std, f_mean(B,N3), f_std
        Ok((out[1].clone(), out[3].clone()))
    }

    /// Forward one padded chunk; returns (e rows, f rows) flattened.
    fn fwd_chunk(&self, batch: usize, rows: &[Vec<f32>]) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let mut cols = split_columns(rows, &self.widths());
        self.fwd_cols(batch, rows.len(), &mut cols)
    }

    /// Energy-only committee UQ through the fused Pallas kernel path —
    /// exposed for the euq benches and dynamic-buffer experiments.
    pub fn euq(&self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        // find an euq artifact
        let prefix = self
            .train_name
            .strip_suffix(&format!("_train_t{}", self.train_batch))
            .unwrap_or("potential")
            .to_string();
        let euq = self
            .engine
            .manifest()
            .with_prefix(&prefix)
            .find(|e| e.meta.get("entry").as_str() == Some("euq"))
            .map(|e| (e.name.clone(), e.meta_usize("batch").unwrap_or(0)))
            .context("no euq artifact")?;
        let (name, batch) = euq;
        let [n3, g, _] = self.widths();
        let take = rows.len().min(batch);
        let mut cols = split_columns(&rows[..take], &self.widths());
        pad_rows(&mut cols[0], take, batch, n3);
        pad_rows(&mut cols[1], take, batch, g);
        let out = self.engine.call(
            &name,
            &[
                self.weights_in(),
                TensorIn::F32(&cols[0]),
                TensorIn::F32(&cols[1]),
            ],
        )?;
        Ok(out[1][..take * self.n_states].to_vec()) // e_mean rows
    }

    /// Validation energy MSE with current weights (learning-curve metric).
    /// Flat path: the flattened validation batch is viewed as strided rows
    /// and column-split straight off the view — no nested row list.
    pub fn validation_mse(&mut self) -> anyhow::Result<Option<f32>> {
        if self.dataset.n_val() == 0 && self.dataset.n_train() == 0 {
            return Ok(None);
        }
        let batch = *self.fwd_names.keys().last().unwrap();
        let (xs, ys, real) = self.dataset.val_batch(batch);
        let view = BatchView::from_parts(&xs, batch, self.input_row_len())
            .context("validation batch shape mismatch")?;
        // persistent scratch (taken out to split the borrow): column
        // staging reuses last call's capacity, no fresh allocations
        let widths = self.widths();
        let mut scratch = std::mem::take(&mut self.in_scratch);
        let result = self.fwd_cols(batch, batch, scratch.split_range(&view, 0, batch, &widths));
        self.in_scratch = scratch;
        let (e, _f) = result?;
        let s = self.n_states;
        let yl = self.label_row_len();
        let mut mse = 0.0f32;
        for i in 0..real {
            for k in 0..s {
                let d = e[i * s + k] - ys[i * yl + k];
                mse += d * d;
            }
        }
        Ok(Some(mse / (real * s) as f32))
    }

    fn train_step(&mut self) -> anyhow::Result<f32> {
        let t = self.train_batch;
        // row shapes and scratches are hoisted before `minibatch`: its
        // returned slices keep the dataset mutably borrowed, so only
        // disjoint-field accesses are legal afterwards
        let in_len = self.input_row_len();
        let lab_len = self.label_row_len();
        let widths = self.widths();
        let lab_widths = [self.n_states, self.n_atoms * 3];
        let mut in_scratch = std::mem::take(&mut self.in_scratch);
        let mut lab_scratch = std::mem::take(&mut self.lab_scratch);
        // flat path: the minibatch is gathered into the dataset's reused
        // scratch and viewed as strided rows — no nested row lists and no
        // per-step sample copies
        let (xs, ys) = self.dataset.minibatch(t);
        let in_view =
            BatchView::from_parts(xs, t, in_len).context("minibatch input shape mismatch")?;
        let lab_view =
            BatchView::from_parts(ys, t, lab_len).context("minibatch label shape mismatch")?;
        let in_cols = in_scratch.split_range(&in_view, 0, t, &widths);
        let lab_cols = lab_scratch.split_range(&lab_view, 0, t, &lab_widths);
        let out = self.engine.call(
            &self.train_name,
            &[
                match &self.w_shared {
                    Some(p) => TensorIn::Shared(p),
                    None => TensorIn::F32(&self.w),
                },
                TensorIn::F32(&self.opt),
                TensorIn::F32(&in_cols[0]),
                TensorIn::F32(&in_cols[1]),
                TensorIn::F32(&in_cols[2]),
                TensorIn::F32(&lab_cols[0]),
                TensorIn::F32(&lab_cols[1]),
            ],
        );
        self.in_scratch = in_scratch;
        self.lab_scratch = lab_scratch;
        let out = out?;
        let mut it = out.into_iter();
        self.w = it.next().unwrap();
        self.w_shared = None;
        self.opt = it.next().unwrap();
        let loss = it.next().unwrap()[0];
        Ok(loss)
    }
}

impl Model for HloPotentialModel {
    fn predict(&mut self, list_data_to_pred: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let batches: Vec<usize> = self.fwd_names.keys().copied().collect();
        let mut out = Vec::with_capacity(list_data_to_pred.len());
        let mut off = 0;
        for (batch, used) in plan_chunks(list_data_to_pred.len(), &batches) {
            let rows = &list_data_to_pred[off..off + used];
            match self.fwd_chunk(batch, rows) {
                Ok((e, f)) => {
                    let s = self.n_states;
                    let n3 = self.n_atoms * 3;
                    for i in 0..used {
                        let mut row = Vec::with_capacity(s + n3);
                        row.extend_from_slice(&e[i * s..(i + 1) * s]);
                        row.extend_from_slice(&f[i * n3..(i + 1) * n3]);
                        out.push(row);
                    }
                }
                Err(_) => {
                    // degrade gracefully: zeroed predictions signal
                    // "unreliable" to the controller/generators
                    for _ in 0..used {
                        out.push(vec![0.0; self.output_row_len()]);
                    }
                }
            }
            off += used;
        }
        out
    }

    /// Native flat path: column splitting reads rows straight off the
    /// strided view into the persistent [`ColumnScratch`] and each output row is the
    /// energy block + force block written contiguously into one [`Batch`].
    fn predict_batch(&mut self, view: &BatchView<'_>) -> RowBlock {
        let batches: Vec<usize> = self.fwd_names.keys().copied().collect();
        let s = self.n_states;
        let n3 = self.n_atoms * 3;
        let widths = self.widths();
        let mut out = Batch::with_capacity(view.rows(), s + n3);
        let zero = vec![0.0; self.output_row_len()];
        let mut off = 0;
        // persistent scratch (taken out to split the borrow): every chunk's
        // column staging reuses the buffers of the one before it
        let mut scratch = std::mem::take(&mut self.in_scratch);
        for (chunk_b, used) in plan_chunks(view.rows(), &batches) {
            let cols = scratch.split_range(view, off, off + used, &widths);
            match self.fwd_cols(chunk_b, used, cols) {
                Ok((e, f)) => {
                    for i in 0..used {
                        out.push_row_concat(&[
                            &e[i * s..(i + 1) * s],
                            &f[i * n3..(i + 1) * n3],
                        ]);
                    }
                }
                Err(_) => {
                    for _ in 0..used {
                        out.push_row(&zero);
                    }
                }
            }
            off += used;
        }
        self.in_scratch = scratch;
        out.into_row_block()
    }

    fn update(&mut self, weight_array: &[f32]) {
        if weight_array.len() == self.param_size {
            self.w_shared = None;
            self.w.copy_from_slice(weight_array);
        }
    }

    fn update_from(&mut self, weights: &Payload) {
        // native flat path: adopt the trainer's shared buffer (refcount
        // bump) instead of copying it into the owned weight array
        if weights.len() == self.param_size {
            self.w_shared = Some(weights.clone());
        }
    }

    fn get_weight(&self) -> Vec<f32> {
        self.weights_slice().to_vec()
    }

    fn get_weight_payload(&self) -> Payload {
        match &self.w_shared {
            Some(p) => p.clone(),
            None => Payload::from(&self.w[..]),
        }
    }

    fn get_weight_size(&self) -> usize {
        self.param_size
    }

    fn add_trainingset(&mut self, datapoints: &[(Vec<f32>, Vec<f32>)]) {
        self.dataset.add(datapoints);
    }

    fn add_trainingset_batch(&mut self, datapoints: &DatapointView<'_>) {
        // native flat path: pairs stream straight from the decoded payload
        // into the dataset, skipping the nested (Vec, Vec) staging list
        self.dataset.add_view(datapoints);
    }

    fn retrain(&mut self, interrupt: &mut dyn FnMut() -> bool) -> bool {
        if self.dataset.is_empty() {
            return false;
        }
        self.last_round_epochs = 0;
        for _ in 0..self.opts.epochs_per_round {
            match self.train_step() {
                Ok(loss) => self.last_loss = Some(loss),
                Err(_) => break,
            }
            self.last_round_epochs += 1;
            if interrupt() {
                break;
            }
        }
        self.rounds += 1;
        match (self.opts.stop_below_loss, self.last_loss) {
            (Some(th), Some(loss)) => loss < th,
            _ => false,
        }
    }

    fn last_loss(&self) -> Option<f32> {
        self.last_loss
    }

    fn last_round_epochs(&self) -> u64 {
        self.last_round_epochs
    }

    fn upload_stats(&self) -> Option<crate::runtime::UploadStats> {
        Some(self.engine.upload_stats())
    }

    fn save_progress(&mut self) {
        self.write_checkpoint();
    }

    fn stop_run(&mut self) {
        self.write_checkpoint();
    }
}
