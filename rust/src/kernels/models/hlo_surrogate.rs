//! The CNN thermo-fluid surrogate as a PAL model kernel
//! (`surrogate1_{fwd,train,init}` artifacts), one committee member per rank.
//!
//! Wire formats (shared with [`crate::kernels::generators::PsoGenerator`]
//! and [`crate::kernels::oracles::ChannelFlowOracle`]):
//! `data_to_pred` row = flattened occupancy grid (H*W);
//! prediction row = `[C_f, St]`; datapoint = `(grid, [C_f, St])`.

use std::collections::BTreeMap;

use anyhow::Context;

use crate::comm::bus::Payload;
use crate::data::batch::{Batch, BatchView, DatapointView, RowBlock};
use crate::data::Dataset;
use crate::kernels::{Mode, Model};
use crate::runtime::{Engine, Manifest, TensorIn};

use super::util::{pad_rows, plan_chunks};

/// One committee member of the CNN surrogate.
pub struct HloSurrogateModel {
    engine: Engine,
    #[allow(dead_code)]
    mode: Mode,
    grid: usize,
    n_out: usize,
    param_size: usize,
    #[allow(dead_code)]
    opt_size: usize,
    fwd_names: BTreeMap<usize, String>,
    train_name: String,
    train_batch: usize,
    w: Vec<f32>,
    /// Weights adopted from a shared wire payload (`update_from`); cleared
    /// whenever `w` is written locally.
    w_shared: Option<Payload>,
    opt: Vec<f32>,
    dataset: Dataset,
    last_loss: Option<f32>,
    pub epochs_per_round: usize,
    rounds: u64,
}

impl HloSurrogateModel {
    pub fn new(manifest: Manifest, mode: Mode, seed: u32) -> anyhow::Result<Self> {
        let engine = Engine::new(manifest)?;
        let init = engine.entry("surrogate1_init")?;
        anyhow::ensure!(init.meta_usize("n_members")? == 1, "need single-member surrogate");
        let grid = init.meta_usize("grid")?;
        let n_out = init.meta_usize("n_out")?;
        let param_size = init.meta_usize("param_size")?;
        let opt_size = init.meta_usize("opt_size")?;
        let mut fwd_names = BTreeMap::new();
        let mut train_name = None;
        let mut train_batch = 0;
        for e in engine.manifest().with_prefix("surrogate1_") {
            match e.meta.get("entry").as_str() {
                Some("fwd") => {
                    fwd_names.insert(e.meta_usize("batch")?, e.name.clone());
                }
                Some("train") => {
                    train_batch = e.meta_usize("batch")?;
                    train_name = Some(e.name.clone());
                }
                _ => {}
            }
        }
        let train_name = train_name.context("no surrogate train artifact")?;
        let w = engine.call("surrogate1_init", &[TensorIn::U32(seed)])?.remove(0);
        Ok(HloSurrogateModel {
            engine,
            mode,
            grid,
            n_out,
            param_size,
            opt_size,
            fwd_names,
            train_name,
            train_batch,
            w,
            w_shared: None,
            opt: vec![0.0; opt_size],
            dataset: Dataset::new(0.15, seed as u64 ^ 0xCFD),
            last_loss: None,
            epochs_per_round: 32,
            rounds: 0,
        })
    }

    pub fn input_row_len(&self) -> usize {
        self.grid * self.grid
    }

    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    pub fn n_train(&self) -> usize {
        self.dataset.n_train()
    }

    /// Active weights: the adopted shared payload when one is held, the
    /// owned buffer otherwise.
    fn weights_slice(&self) -> &[f32] {
        match &self.w_shared {
            Some(p) => p.as_slice(),
            None => &self.w,
        }
    }

    /// Active weights as an engine input. An adopted shared payload goes in
    /// as [`TensorIn::Shared`], so repeat calls between weight syncs hit the
    /// engine's upload cache instead of re-staging `param_size` floats.
    fn weights_in(&self) -> TensorIn<'_> {
        match &self.w_shared {
            Some(p) => TensorIn::Shared(p),
            None => TensorIn::F32(&self.w),
        }
    }

    /// Forward one stacked chunk (`used` live rows in `flat`): pads to the
    /// artifact batch, runs the forward, extracts `y_mean` — the single
    /// place both predict paths get the output-tensor layout from.
    fn fwd_flat(&self, batch: usize, used: usize, flat: &mut Vec<f32>) -> anyhow::Result<Vec<f32>> {
        let name = &self.fwd_names[&batch];
        pad_rows(flat, used, batch, self.input_row_len());
        let out = self.engine.call(name, &[self.weights_in(), TensorIn::F32(flat)])?;
        Ok(out[1].clone()) // y_mean (B, n_out)
    }

    fn fwd_chunk(&self, batch: usize, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        let mut flat = Vec::with_capacity(batch * self.input_row_len());
        for r in rows {
            flat.extend_from_slice(r);
        }
        self.fwd_flat(batch, rows.len(), &mut flat)
    }

    fn train_step(&mut self) -> anyhow::Result<f32> {
        // the minibatch borrows the dataset's gather scratch, so only
        // disjoint-field access (engine, weights, opt) is legal below
        let (xs, ys) = self.dataset.minibatch(self.train_batch);
        let out = self.engine.call(
            &self.train_name,
            &[
                match &self.w_shared {
                    Some(p) => TensorIn::Shared(p),
                    None => TensorIn::F32(&self.w),
                },
                TensorIn::F32(&self.opt),
                TensorIn::F32(xs),
                TensorIn::F32(ys),
            ],
        )?;
        let mut it = out.into_iter();
        self.w = it.next().unwrap();
        self.w_shared = None;
        self.opt = it.next().unwrap();
        Ok(it.next().unwrap()[0])
    }

    /// Validation MSE (learning-curve metric for the thermo-fluid example).
    /// Flat path: the flattened validation batch feeds the forward
    /// directly — no nested row list is ever materialized.
    pub fn validation_mse(&mut self) -> anyhow::Result<Option<f32>> {
        if self.dataset.n_val() == 0 && self.dataset.n_train() == 0 {
            return Ok(None);
        }
        let batch = *self.fwd_names.keys().last().unwrap();
        let (mut xs, ys, real) = self.dataset.val_batch(batch);
        anyhow::ensure!(
            xs.len() == batch * self.input_row_len(),
            "validation batch shape mismatch"
        );
        let y = self.fwd_flat(batch, batch, &mut xs)?;
        let o = self.n_out;
        let mut mse = 0.0;
        for i in 0..real {
            for k in 0..o {
                let d = y[i * o + k] - ys[i * o + k];
                mse += d * d;
            }
        }
        Ok(Some(mse / (real * o) as f32))
    }
}

impl Model for HloSurrogateModel {
    fn predict(&mut self, list_data_to_pred: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let batches: Vec<usize> = self.fwd_names.keys().copied().collect();
        let mut out = Vec::with_capacity(list_data_to_pred.len());
        let mut off = 0;
        for (batch, used) in plan_chunks(list_data_to_pred.len(), &batches) {
            let rows = &list_data_to_pred[off..off + used];
            match self.fwd_chunk(batch, rows) {
                Ok(y) => {
                    for i in 0..used {
                        out.push(y[i * self.n_out..(i + 1) * self.n_out].to_vec());
                    }
                }
                Err(_) => {
                    for _ in 0..used {
                        out.push(vec![0.0; self.n_out]);
                    }
                }
            }
            off += used;
        }
        out
    }

    /// Native flat path: occupancy grids stack straight from the strided
    /// view into one reusable chunk buffer; outputs land in one contiguous
    /// block.
    fn predict_batch(&mut self, view: &BatchView<'_>) -> RowBlock {
        let batches: Vec<usize> = self.fwd_names.keys().copied().collect();
        let w = self.input_row_len();
        let mut out = Batch::with_capacity(view.rows(), self.n_out);
        let zero = vec![0.0; self.n_out];
        let mut flat: Vec<f32> = Vec::new();
        let mut off = 0;
        for (chunk_b, used) in plan_chunks(view.rows(), &batches) {
            flat.clear();
            flat.reserve(chunk_b * w);
            for i in off..off + used {
                flat.extend_from_slice(view.row(i));
            }
            match self.fwd_flat(chunk_b, used, &mut flat) {
                Ok(y) => {
                    for i in 0..used {
                        out.push_row(&y[i * self.n_out..(i + 1) * self.n_out]);
                    }
                }
                Err(_) => {
                    for _ in 0..used {
                        out.push_row(&zero);
                    }
                }
            }
            off += used;
        }
        out.into_row_block()
    }

    fn update(&mut self, weight_array: &[f32]) {
        if weight_array.len() == self.param_size {
            self.w_shared = None;
            self.w.copy_from_slice(weight_array);
        }
    }

    fn update_from(&mut self, weights: &Payload) {
        // native flat path: adopt the trainer's shared buffer (refcount
        // bump) instead of copying it into the owned weight array
        if weights.len() == self.param_size {
            self.w_shared = Some(weights.clone());
        }
    }

    fn get_weight(&self) -> Vec<f32> {
        self.weights_slice().to_vec()
    }

    fn get_weight_payload(&self) -> Payload {
        match &self.w_shared {
            Some(p) => p.clone(),
            None => Payload::from(&self.w[..]),
        }
    }

    fn get_weight_size(&self) -> usize {
        self.param_size
    }

    fn add_trainingset(&mut self, datapoints: &[(Vec<f32>, Vec<f32>)]) {
        self.dataset.add(datapoints);
    }

    fn add_trainingset_batch(&mut self, datapoints: &DatapointView<'_>) {
        // native flat path: pairs stream straight from the decoded payload
        // into the dataset, skipping the nested (Vec, Vec) staging list
        self.dataset.add_view(datapoints);
    }

    fn retrain(&mut self, interrupt: &mut dyn FnMut() -> bool) -> bool {
        if self.dataset.is_empty() {
            return false;
        }
        for _ in 0..self.epochs_per_round {
            match self.train_step() {
                Ok(loss) => self.last_loss = Some(loss),
                Err(_) => break,
            }
            if interrupt() {
                break;
            }
        }
        self.rounds += 1;
        false
    }

    fn last_loss(&self) -> Option<f32> {
        self.last_loss
    }

    fn upload_stats(&self) -> Option<crate::runtime::UploadStats> {
        Some(self.engine.upload_stats())
    }
}
