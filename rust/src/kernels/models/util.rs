//! Shared plumbing for HLO-backed models: row splitting and batch planning.

use crate::data::batch::BatchView;

/// Split a list of equal-width rows into contiguous column blocks.
///
/// `widths` partitions each row; returns one flat column-major-batch array
/// per block: `out[b]` holds `rows.len() * widths[b]` values.
pub fn split_columns(rows: &[Vec<f32>], widths: &[usize]) -> Vec<Vec<f32>> {
    let row_len: usize = widths.iter().sum();
    let mut out: Vec<Vec<f32>> =
        widths.iter().map(|w| Vec::with_capacity(w * rows.len())).collect();
    for row in rows {
        assert_eq!(row.len(), row_len, "row width mismatch");
        let mut off = 0;
        for (b, &w) in widths.iter().enumerate() {
            out[b].extend_from_slice(&row[off..off + w]);
            off += w;
        }
    }
    out
}

/// [`split_columns`] over rows `lo..hi` of a strided [`BatchView`] — the
/// flat-data-plane twin used by native `predict_batch` implementations: no
/// nested row list is ever materialized.
pub fn split_columns_range(
    view: &BatchView<'_>,
    lo: usize,
    hi: usize,
    widths: &[usize],
) -> Vec<Vec<f32>> {
    let row_len: usize = widths.iter().sum();
    let rows = hi - lo;
    let mut out: Vec<Vec<f32>> = widths.iter().map(|w| Vec::with_capacity(w * rows)).collect();
    for i in lo..hi {
        let row = view.row(i);
        assert_eq!(row.len(), row_len, "row width mismatch");
        let mut off = 0;
        for (b, &w) in widths.iter().enumerate() {
            out[b].extend_from_slice(&row[off..off + w]);
            off += w;
        }
    }
    out
}

/// Persistent column-split scratch: the per-model twin of
/// [`split_columns_range`] that *clears* its column buffers instead of
/// reallocating them, so the HLO forward/train staging is allocation-free
/// in steady state (every call after the first at a given shape reuses the
/// previous call's capacity).
///
/// One instance per distinct `widths` layout — a model keeps one for its
/// input columns and one for its label columns.
#[derive(Debug, Default)]
pub struct ColumnScratch {
    cols: Vec<Vec<f32>>,
}

impl ColumnScratch {
    pub fn new() -> Self {
        ColumnScratch::default()
    }

    /// [`split_columns_range`] into the reused buffers. Returns the filled
    /// column blocks; they stay valid (and writable, e.g. for padding)
    /// until the next call.
    pub fn split_range(
        &mut self,
        view: &BatchView<'_>,
        lo: usize,
        hi: usize,
        widths: &[usize],
    ) -> &mut [Vec<f32>] {
        let row_len: usize = widths.iter().sum();
        self.cols.resize_with(widths.len(), Vec::new);
        for (b, col) in self.cols.iter_mut().enumerate() {
            col.clear();
            col.reserve(widths[b] * (hi - lo));
        }
        for i in lo..hi {
            let row = view.row(i);
            assert_eq!(row.len(), row_len, "row width mismatch");
            let mut off = 0;
            for (b, &w) in widths.iter().enumerate() {
                self.cols[b].extend_from_slice(&row[off..off + w]);
                off += w;
            }
        }
        &mut self.cols
    }

    /// Total retained capacity across column buffers (diagnostics: should
    /// plateau on hot loops).
    pub fn capacity_values(&self) -> usize {
        self.cols.iter().map(|c| c.capacity()).sum()
    }
}

/// Plan chunking of `n` rows over the available fixed batch sizes
/// (ascending). Returns a list of `(batch_size, rows_used)` chunks covering
/// all `n` rows; the final chunk may be padded (`rows_used < batch_size`).
pub fn plan_chunks(n: usize, batches: &[usize]) -> Vec<(usize, usize)> {
    assert!(!batches.is_empty(), "no fwd batch variants in manifest");
    let mut sorted = batches.to_vec();
    sorted.sort_unstable();
    let largest = *sorted.last().unwrap();
    let mut plan = Vec::new();
    let mut remaining = n;
    while remaining > 0 {
        if remaining >= largest {
            plan.push((largest, largest));
            remaining -= largest;
        } else {
            // smallest variant that covers the remainder
            let b = *sorted.iter().find(|&&b| b >= remaining).unwrap_or(&largest);
            plan.push((b, remaining));
            remaining = 0;
        }
    }
    plan
}

/// Pad `rows`-rows flat array of width `w` up to `batch` rows by repeating
/// the final row (keeps values in-distribution for the padded lanes).
pub fn pad_rows(data: &mut Vec<f32>, rows: usize, batch: usize, w: usize) {
    debug_assert_eq!(data.len(), rows * w);
    if rows == 0 {
        data.resize(batch * w, 0.0);
        return;
    }
    let last: Vec<f32> = data[(rows - 1) * w..rows * w].to_vec();
    for _ in rows..batch {
        data.extend_from_slice(&last);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_columns_partitions() {
        let rows = vec![vec![1.0, 2.0, 3.0, 4.0], vec![5.0, 6.0, 7.0, 8.0]];
        let cols = split_columns(&rows, &[3, 1]);
        assert_eq!(cols[0], vec![1.0, 2.0, 3.0, 5.0, 6.0, 7.0]);
        assert_eq!(cols[1], vec![4.0, 8.0]);
    }

    #[test]
    fn split_columns_range_matches_nested() {
        let rows = vec![
            vec![1.0, 2.0, 3.0, 4.0],
            vec![5.0, 6.0, 7.0, 8.0],
            vec![9.0, 10.0, 11.0, 12.0],
        ];
        let batch = crate::data::batch::Batch::from_rows(&rows).unwrap();
        let all = split_columns_range(&batch.view(), 0, 3, &[3, 1]);
        assert_eq!(all, split_columns(&rows, &[3, 1]));
        let tail = split_columns_range(&batch.view(), 1, 3, &[3, 1]);
        assert_eq!(tail, split_columns(&rows[1..], &[3, 1]));
    }

    #[test]
    fn column_scratch_matches_split_columns_range_and_reuses_capacity() {
        let rows: Vec<Vec<f32>> =
            (0..6).map(|i| (0..8).map(|k| (i * 8 + k) as f32).collect()).collect();
        let batch = crate::data::batch::Batch::from_rows(&rows).unwrap();
        let widths = [5usize, 2, 1];
        let mut scratch = ColumnScratch::new();
        let got = scratch.split_range(&batch.view(), 1, 5, &widths).to_vec();
        assert_eq!(got, split_columns_range(&batch.view(), 1, 5, &widths));
        // steady state: repeated same-shape calls never grow capacity
        let cap = scratch.capacity_values();
        for _ in 0..10 {
            let again = scratch.split_range(&batch.view(), 1, 5, &widths);
            assert_eq!(again.len(), 3);
        }
        assert_eq!(scratch.capacity_values(), cap, "scratch must clear, not reallocate");
        // shrinking the range reuses the same buffers too
        let small = scratch.split_range(&batch.view(), 0, 2, &widths).to_vec();
        assert_eq!(small, split_columns_range(&batch.view(), 0, 2, &widths));
        assert_eq!(scratch.capacity_values(), cap);
    }

    #[test]
    fn plan_exact_fit() {
        assert_eq!(plan_chunks(16, &[1, 16, 89]), vec![(16, 16)]);
        assert_eq!(plan_chunks(89, &[1, 16, 89]), vec![(89, 89)]);
    }

    #[test]
    fn plan_chunks_large_n() {
        let plan = plan_chunks(200, &[1, 16, 89]);
        let used: usize = plan.iter().map(|&(_, u)| u).sum();
        assert_eq!(used, 200);
        assert_eq!(plan[0], (89, 89));
        assert_eq!(plan[1], (89, 89));
        // remainder 22 → smallest variant >= 22 is 89
        assert_eq!(plan[2], (89, 22));
    }

    #[test]
    fn plan_small_n_picks_tight_variant() {
        assert_eq!(plan_chunks(3, &[1, 16, 89]), vec![(16, 3)]);
        assert_eq!(plan_chunks(1, &[1, 16, 89]), vec![(1, 1)]);
    }

    #[test]
    fn pad_repeats_last_row() {
        let mut d = vec![1.0, 2.0, 3.0, 4.0];
        pad_rows(&mut d, 2, 4, 2);
        assert_eq!(d, vec![1.0, 2.0, 3.0, 4.0, 3.0, 4.0, 3.0, 4.0]);
    }

    #[test]
    fn pad_empty_zero_fills() {
        let mut d: Vec<f32> = vec![];
        pad_rows(&mut d, 0, 2, 3);
        assert_eq!(d, vec![0.0; 6]);
    }
}
