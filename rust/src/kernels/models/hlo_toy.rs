//! The SI toy model (linear 4→4) as an HLO-backed PAL kernel — used by the
//! quickstart example to demonstrate the full artifact path with negligible
//! compute.
//!
//! The toy artifacts are lowered with the full 3-member committee in one
//! program (`toy_fwd_b20` takes all members' weights). Each rank owns one
//! member, so the fused forward is fed the member's weights replicated M
//! times and `y_mean` (identical across replicas) is that member's output.

use anyhow::Context;

use crate::comm::bus::Payload;
use crate::data::batch::{Batch, BatchView, DatapointView, RowBlock};
use crate::data::Dataset;
use crate::kernels::{Mode, Model};
use crate::runtime::{Engine, Manifest, TensorIn};

use super::util::pad_rows;

/// One committee member of the SI toy model.
pub struct HloToyModel {
    engine: Engine,
    #[allow(dead_code)]
    mode: Mode,
    n_in: usize,
    n_out: usize,
    n_members: usize,
    param_size: usize,
    #[allow(dead_code)]
    opt_size: usize,
    fwd_name: String,
    fwd_batch: usize,
    train_name: String,
    train_batch: usize,
    w: Vec<f32>,
    /// Weights adopted from a shared wire payload (`update_from`); cleared
    /// whenever `w` is written locally.
    w_shared: Option<Payload>,
    /// Fused-forward staging: this member's weights replicated `n_members`
    /// times, kept as a shared payload so every predict between weight
    /// syncs reuses one buffer (and the engine's upload cache sees one
    /// stable identity). Cleared alongside any weight write.
    w_all_shared: Option<Payload>,
    opt: Vec<f32>,
    dataset: Dataset,
    last_loss: Option<f32>,
    pub epochs_per_round: usize,
}

impl HloToyModel {
    pub fn new(manifest: Manifest, mode: Mode, seed: u32) -> anyhow::Result<Self> {
        let engine = Engine::new(manifest)?;
        let init = engine.entry("toy_init")?;
        let n_in = init.meta_usize("n_in")?;
        let n_out = init.meta_usize("n_out")?;
        let n_members = init.meta_usize("n_members")?;
        let param_size = init.meta_usize("param_size")?;
        let opt_size = init.meta_usize("opt_size")?;
        let mut fwd = None;
        let mut train = None;
        for e in engine.manifest().with_prefix("toy_") {
            match e.meta.get("entry").as_str() {
                Some("fwd") => fwd = Some((e.name.clone(), e.meta_usize("batch")?)),
                Some("train") => train = Some((e.name.clone(), e.meta_usize("batch")?)),
                _ => {}
            }
        }
        let (fwd_name, fwd_batch) = fwd.context("no toy fwd artifact")?;
        let (train_name, train_batch) = train.context("no toy train artifact")?;
        // all members initialized on-device; this rank keeps one slice
        let w_all = engine.call("toy_init", &[TensorIn::U32(0)])?.remove(0);
        let member = (seed as usize) % n_members;
        let w = w_all[member * param_size..(member + 1) * param_size].to_vec();
        Ok(HloToyModel {
            engine,
            mode,
            n_in,
            n_out,
            n_members,
            param_size,
            opt_size,
            fwd_name,
            fwd_batch,
            train_name,
            train_batch,
            w,
            w_shared: None,
            w_all_shared: None,
            opt: vec![0.0; opt_size],
            dataset: Dataset::new(0.2, seed as u64),
            last_loss: None,
            epochs_per_round: 16,
        })
    }

    /// Active weights: the adopted shared payload when one is held, the
    /// owned buffer otherwise.
    fn weights_slice(&self) -> &[f32] {
        match &self.w_shared {
            Some(p) => p.as_slice(),
            None => &self.w,
        }
    }

    /// The member's weights replicated for the fused committee forward,
    /// as a cached shared payload (cheap handle clone). Rebuilt only after
    /// a weight write invalidated the cache — steady-state prediction
    /// re-serves the same buffer, so the engine stages it exactly once.
    fn replicated_weights(&mut self) -> Payload {
        if self.w_all_shared.is_none() {
            let mut w_all = Vec::with_capacity(self.n_members * self.param_size);
            for _ in 0..self.n_members {
                w_all.extend_from_slice(self.weights_slice());
            }
            self.w_all_shared = Some(Payload::from(w_all));
        }
        self.w_all_shared.clone().expect("filled above")
    }

    /// Forward one stacked chunk (`used` live rows already normalized to
    /// `n_in` values each in `flat`): pads to the artifact batch, runs the
    /// fused forward, and extracts `y_mean` — the single place both the
    /// nested and flat predict paths get the output-tensor layout from.
    /// `None` on engine failure (callers degrade to zero rows).
    fn fwd_stacked(&self, w_all: &Payload, used: usize, flat: &mut Vec<f32>) -> Option<Vec<f32>> {
        pad_rows(flat, used, self.fwd_batch, self.n_in);
        match self.engine.call(&self.fwd_name, &[TensorIn::Shared(w_all), TensorIn::F32(flat)]) {
            // outputs: y_all, y_mean (B, n_out) — members identical
            Ok(res) => Some(res[1].clone()),
            Err(_) => None,
        }
    }

    /// Append one row's first `n_in` values to `flat`, zero-padding short
    /// rows (shared input normalization for both predict paths).
    fn stack_normalized(&self, row: &[f32], flat: &mut Vec<f32>) {
        let take = self.n_in.min(row.len());
        flat.extend_from_slice(&row[..take]);
        flat.extend(std::iter::repeat(0.0).take(self.n_in - take));
    }
}

impl Model for HloToyModel {
    fn predict(&mut self, list_data_to_pred: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let b = self.fwd_batch;
        let w_all = self.replicated_weights();
        let mut out = Vec::with_capacity(list_data_to_pred.len());
        let mut flat = Vec::with_capacity(b * self.n_in);
        for chunk in list_data_to_pred.chunks(b) {
            flat.clear();
            for row in chunk {
                self.stack_normalized(row, &mut flat);
            }
            match self.fwd_stacked(&w_all, chunk.len(), &mut flat) {
                Some(y_mean) => {
                    for i in 0..chunk.len() {
                        out.push(y_mean[i * self.n_out..(i + 1) * self.n_out].to_vec());
                    }
                }
                None => {
                    for _ in 0..chunk.len() {
                        out.push(vec![0.0; self.n_out]);
                    }
                }
            }
        }
        out
    }

    /// Native flat path: rows are read straight off the strided view into
    /// one reusable stacking buffer, outputs land in one contiguous block
    /// — no per-row boxing on either side.
    fn predict_batch(&mut self, batch: &BatchView<'_>) -> RowBlock {
        let b = self.fwd_batch;
        let w_all = self.replicated_weights();
        let mut out = Batch::with_capacity(batch.rows(), self.n_out);
        let zero = vec![0.0; self.n_out];
        let mut flat = Vec::with_capacity(b * self.n_in);
        let mut off = 0;
        while off < batch.rows() {
            let used = b.min(batch.rows() - off);
            flat.clear();
            for i in off..off + used {
                self.stack_normalized(batch.row(i), &mut flat);
            }
            match self.fwd_stacked(&w_all, used, &mut flat) {
                Some(y_mean) => {
                    for i in 0..used {
                        out.push_row(&y_mean[i * self.n_out..(i + 1) * self.n_out]);
                    }
                }
                None => {
                    for _ in 0..used {
                        out.push_row(&zero);
                    }
                }
            }
            off += used;
        }
        out.into_row_block()
    }

    fn update(&mut self, weight_array: &[f32]) {
        if weight_array.len() == self.param_size {
            self.w_shared = None;
            self.w_all_shared = None;
            self.w.copy_from_slice(weight_array);
        }
    }

    fn update_from(&mut self, weights: &Payload) {
        // native flat path: adopt the trainer's shared buffer (refcount
        // bump) instead of copying it into the owned weight array
        if weights.len() == self.param_size {
            self.w_shared = Some(weights.clone());
            self.w_all_shared = None;
        }
    }

    fn get_weight(&self) -> Vec<f32> {
        self.weights_slice().to_vec()
    }

    fn get_weight_payload(&self) -> Payload {
        match &self.w_shared {
            Some(p) => p.clone(),
            None => Payload::from(&self.w[..]),
        }
    }

    fn get_weight_size(&self) -> usize {
        self.param_size
    }

    fn add_trainingset(&mut self, datapoints: &[(Vec<f32>, Vec<f32>)]) {
        self.dataset.add(datapoints);
    }

    fn add_trainingset_batch(&mut self, datapoints: &DatapointView<'_>) {
        // native flat path: pairs stream straight from the decoded payload
        // into the dataset, skipping the nested (Vec, Vec) staging list
        self.dataset.add_view(datapoints);
    }

    fn retrain(&mut self, interrupt: &mut dyn FnMut() -> bool) -> bool {
        if self.dataset.is_empty() {
            return false;
        }
        for _ in 0..self.epochs_per_round {
            // the minibatch borrows the dataset's gather scratch, so only
            // disjoint-field access (engine, weights, opt) is legal below
            let (xs, ys) = self.dataset.minibatch(self.train_batch);
            match self.engine.call(
                &self.train_name,
                &[
                    match &self.w_shared {
                        Some(p) => TensorIn::Shared(p),
                        None => TensorIn::F32(&self.w),
                    },
                    TensorIn::F32(&self.opt),
                    TensorIn::F32(xs),
                    TensorIn::F32(ys),
                ],
            ) {
                Ok(res) => {
                    let mut it = res.into_iter();
                    self.w = it.next().unwrap();
                    self.w_shared = None;
                    self.w_all_shared = None;
                    self.opt = it.next().unwrap();
                    self.last_loss = Some(it.next().unwrap()[0]);
                }
                Err(_) => break,
            }
            if interrupt() {
                break;
            }
        }
        false
    }

    fn last_loss(&self) -> Option<f32> {
        self.last_loss
    }

    fn upload_stats(&self) -> Option<crate::runtime::UploadStats> {
        Some(self.engine.upload_stats())
    }
}
