//! Wire protocol: tags and message conventions between PAL kernels.
//!
//! Mirrors the data flows of the paper's Fig. 4:
//!
//! * **red** — generators → (gather) → Exchange → (bcast) → predictors
//! * **blue** — predictors → (gather) → Exchange → `prediction_check` →
//!   (scatter) → generators
//! * **green** — Exchange → Manager (selected inputs) → oracle → Manager
//! * **yellow** — Manager → (bcast) → trainers (labeled datapoints)
//! * weights — trainer *i* → predictor *i* directly (paper §2.4: "trained
//!   model weights are periodically copied directly to the prediction
//!   kernel")
//! * control — stop requests to Manager; shutdown fan-out from Manager.

/// generator → Exchange: `[stop_flag, data_to_pred...]` (red flow).
pub const TAG_GEN_TO_PRED: u32 = 10;
/// Exchange → predictors: packed list of per-generator inputs (red flow).
pub const TAG_PRED_IN: u32 = 11;
/// predictor → Exchange: packed list of per-generator predictions (blue).
pub const TAG_PRED_OUT: u32 = 12;
/// Exchange → generators: checked prediction for that generator (blue).
pub const TAG_GENE_IN: u32 = 13;
/// generator → Exchange: 1-element size header preceding the payload, sent
/// only when `fixed_size_data = false` (SI §S3: "sizes of data are passed
/// first for every MPI communication ... thus lower efficiency").
pub const TAG_GEN_SIZE: u32 = 14;

/// Exchange → Manager: packed list of inputs selected for labeling (green).
pub const TAG_ORCL_SELECT: u32 = 20;
/// Manager → oracle: one input to label (green).
pub const TAG_TO_ORACLE: u32 = 21;
/// oracle → Manager: packed `[input, label]` (green).
pub const TAG_ORACLE_RESULT: u32 = 22;

/// Manager → trainers: packed labeled datapoints (yellow).
pub const TAG_TRAIN_DATA: u32 = 30;
/// trainer i → predictor i: flat weight array.
pub const TAG_WEIGHTS: u32 = 31;
/// trainer → Manager: `[loss]` after a retraining round (telemetry).
pub const TAG_RETRAIN_DONE: u32 = 32;

/// Manager → predictors: packed oracle-buffer inputs for re-scoring
/// (`dynamic_orcale_list`, SI Utilities).
pub const TAG_RESCORE_REQ: u32 = 40;
/// predictor → Manager: packed per-input predictions.
pub const TAG_RESCORE_RESP: u32 = 41;

/// any kernel → Manager: request workflow shutdown (`stop_run = true`).
pub const TAG_STOP: u32 = 90;
/// Manager → everyone: drain and exit.
pub const TAG_SHUTDOWN: u32 = 91;

/// Encode a generator's step message: `[stop_flag, data...]`.
pub fn encode_gen(stop: bool, data: &[f32]) -> Vec<f32> {
    let mut v = Vec::with_capacity(1 + data.len());
    v.push(if stop { 1.0 } else { 0.0 });
    v.extend_from_slice(data);
    v
}

/// Decode a generator's step message.
pub fn decode_gen(msg: &[f32]) -> (bool, &[f32]) {
    let stop = msg.first().map(|&f| f != 0.0).unwrap_or(false);
    (stop, msg.get(1..).unwrap_or(&[]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_encoding_roundtrip() {
        let enc = encode_gen(true, &[1.0, 2.0]);
        let (stop, data) = decode_gen(&enc);
        assert!(stop);
        assert_eq!(data, &[1.0, 2.0]);
        let enc = encode_gen(false, &[]);
        let (stop, data) = decode_gen(&enc);
        assert!(!stop);
        assert!(data.is_empty());
    }

    #[test]
    fn tags_are_distinct() {
        let tags = [
            TAG_GEN_TO_PRED, TAG_PRED_IN, TAG_PRED_OUT, TAG_GENE_IN, TAG_GEN_SIZE,
            TAG_ORCL_SELECT, TAG_TO_ORACLE, TAG_ORACLE_RESULT,
            TAG_TRAIN_DATA, TAG_WEIGHTS, TAG_RETRAIN_DONE,
            TAG_RESCORE_REQ, TAG_RESCORE_RESP, TAG_STOP, TAG_SHUTDOWN,
        ];
        let mut sorted = tags.to_vec();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), tags.len());
    }
}
