//! Wire protocol: tags and message conventions between PAL kernels.
//!
//! Mirrors the data flows of the paper's Fig. 4:
//!
//! * **red** — generators → (gather) → Exchange → (bcast) → predictors
//! * **blue** — predictors → (gather) → Exchange → `prediction_check` →
//!   (scatter) → generators
//! * **green** — Exchange → Manager (selected inputs) → oracle → Manager.
//!   Two dispatch legs exist: the paper's per-label messages
//!   ([`TAG_TO_ORACLE`]/[`TAG_ORACLE_RESULT`]) and the batched oracle plane
//!   ([`TAG_ORACLE_BATCH`]/[`TAG_ORACLE_BATCH_RESULT`]) which coalesces
//!   many inputs per round-trip; wire bytes of the per-label leg are
//!   unchanged, and the batched result frame's packed section is
//!   byte-identical to `pack_datapoints` over its pairs
//! * **yellow** — Manager → (bcast) → trainers (labeled datapoints)
//! * weights — trainer *i* → predictor *i* directly (paper §2.4: "trained
//!   model weights are periodically copied directly to the prediction
//!   kernel")
//! * control — stop requests to Manager; shutdown fan-out from Manager;
//!   rank-down notices from host supervisors ([`TAG_RANK_DOWN`]).

/// generator → Exchange: `[stop_flag, data_to_pred...]` (red flow).
pub const TAG_GEN_TO_PRED: u32 = 10;
/// Exchange → predictors: packed list of per-generator inputs (red flow).
pub const TAG_PRED_IN: u32 = 11;
/// predictor → Exchange: packed list of per-generator predictions (blue).
pub const TAG_PRED_OUT: u32 = 12;
/// Exchange → generators: checked prediction for that generator (blue).
pub const TAG_GENE_IN: u32 = 13;
/// generator → Exchange: 1-element size header preceding the payload, sent
/// only when `fixed_size_data = false` (SI §S3: "sizes of data are passed
/// first for every MPI communication ... thus lower efficiency").
pub const TAG_GEN_SIZE: u32 = 14;
/// Exchange → one shard's predictors: a `PredictBatch` frame — coalesced
/// inputs from several generators (batched exchange mode, red flow).
pub const TAG_PRED_BATCH: u32 = 15;
/// predictor → Exchange: the matching `PredictBatchResult` frame with one
/// output per batched item (batched exchange mode, blue flow).
pub const TAG_PRED_BATCH_RESULT: u32 = 16;

/// Exchange → Manager: packed list of inputs selected for labeling (green).
pub const TAG_ORCL_SELECT: u32 = 20;
/// Manager → oracle: one input to label (green, per-label oracle mode).
pub const TAG_TO_ORACLE: u32 = 21;
/// oracle → Manager: packed `[input, label]` (green, per-label oracle mode).
pub const TAG_ORACLE_RESULT: u32 = 22;
/// Manager → one oracle: an `OracleBatch` frame — a micro-batch of inputs
/// coalesced by the [`crate::coordinator::oracle_plane::OracleScheduler`]
/// under one id (green, batched oracle mode).
pub const TAG_ORACLE_BATCH: u32 = 23;
/// oracle → Manager: the matching `OracleBatchResult` frame — interleaved
/// `(input, label)` pairs, one per batched item in dispatch order, echoing
/// the batch id (green, batched oracle mode). Legacy layout: superseded by
/// [`TAG_ORACLE_LABELS`], kept for per-frame compatibility tests and
/// mixed-version runs.
pub const TAG_ORACLE_BATCH_RESULT: u32 = 24;
/// oracle → Manager: labels-only `OracleLabels` frame — one label row per
/// batched item in dispatch order under the echoed batch id, layout
/// `[id_hi, id_lo, pack of label rows]` (same as `PredictBatchResult`).
/// The Manager retains each dispatched input block and pairs label row `i`
/// with retained input row `i`, so the inputs never travel back over the
/// wire — roughly halving green-flow result bytes at typical batch sizes.
pub const TAG_ORACLE_LABELS: u32 = 25;

/// Manager → trainers: packed labeled datapoints (yellow). Encoded from
/// the Manager's flat [`crate::data::batch::DatapointBlock`] via
/// [`crate::comm::codec::encode_train_block_into`] and decoded on the
/// train host as borrowed views
/// ([`crate::comm::codec::decode_train_block_views`]); wire bytes are
/// identical to the legacy nested `pack_datapoints` format.
pub const TAG_TRAIN_DATA: u32 = 30;
/// trainer i → predictor i: flat weight array, shipped as one shared
/// payload per sync (`Model::get_weight_payload`) that every shard replica
/// adopts by refcount (`Model::update_from`) — zero per-destination copies.
pub const TAG_WEIGHTS: u32 = 31;
/// trainer → Manager: `[loss]` after a retraining round (telemetry).
pub const TAG_RETRAIN_DONE: u32 = 32;

/// Manager → predictors: packed oracle-buffer inputs for re-scoring
/// (`dynamic_orcale_list`, SI Utilities).
pub const TAG_RESCORE_REQ: u32 = 40;
/// predictor → Manager: packed per-input predictions.
pub const TAG_RESCORE_RESP: u32 = 41;

/// any kernel → Manager: request workflow shutdown (`stop_run = true`).
pub const TAG_STOP: u32 = 90;
/// Manager → everyone: drain and exit.
pub const TAG_SHUTDOWN: u32 = 91;
/// supervisor → Manager/Exchange: `[rank]` of a host that died (panic or
/// injected fault). Sent from the joining supervisor thread via a
/// [`crate::comm::bus::ControlHandle`], so it is delivered even though the
/// dead rank's own endpoint is gone.
pub const TAG_RANK_DOWN: u32 = 92;

/// Encode a generator's step message into a reusable scratch buffer:
/// `[stop_flag, data...]`. Clears `out` first.
pub fn encode_gen_into(stop: bool, data: &[f32], out: &mut Vec<f32>) {
    out.clear();
    out.reserve(1 + data.len());
    out.push(if stop { 1.0 } else { 0.0 });
    out.extend_from_slice(data);
}

/// Encode a generator's step message: `[stop_flag, data...]`.
pub fn encode_gen(stop: bool, data: &[f32]) -> Vec<f32> {
    let mut v = Vec::new();
    encode_gen_into(stop, data, &mut v);
    v
}

/// Decode a generator's step message.
pub fn decode_gen(msg: &[f32]) -> (bool, &[f32]) {
    let stop = msg.first().map(|&f| f != 0.0).unwrap_or(false);
    (stop, msg.get(1..).unwrap_or(&[]))
}

// ---------------------------------------------------------------------------
// Batch frames (batched exchange mode)
// ---------------------------------------------------------------------------
//
// `PredictBatch` (Exchange → shard) and `PredictBatchResult` (predictor →
// Exchange) share one self-describing layout over the flat-f32 wire:
//
// ```text
// [ id_hi, id_lo, <codec::pack of the item list> ]
// ```
//
// The batch id is split into two 24-bit halves so it stays exact in f32
// (ids are sequence numbers; 2^48 batches outlives any run).

const ID_HALF: u64 = 1 << 24;

/// Clear `out` and write the two-value 48-bit id header every frame
/// encoder shares (flat and nested encoders must never diverge here).
fn push_frame_id(id: u64, out: &mut Vec<f32>) {
    debug_assert!(id < ID_HALF * ID_HALF, "batch id overflows 48 bits");
    out.clear();
    out.push(((id / ID_HALF) % ID_HALF) as f32);
    out.push((id % ID_HALF) as f32);
}

fn encode_frame_into<S: AsRef<[f32]>>(id: u64, items: &[S], out: &mut Vec<f32>) {
    push_frame_id(id, out);
    crate::comm::codec::pack_into(items, out);
}

/// Split a frame into its 48-bit id and the packed item payload.
fn decode_frame_id(msg: &[f32]) -> Option<(u64, &[f32])> {
    let hi = *msg.first()?;
    let lo = *msg.get(1)?;
    if hi < 0.0 || lo < 0.0 || hi.fract() != 0.0 || lo.fract() != 0.0 {
        return None;
    }
    let (hi, lo) = (hi as u64, lo as u64);
    if hi >= ID_HALF || lo >= ID_HALF {
        return None;
    }
    Some((hi * ID_HALF + lo, &msg[2..]))
}

fn decode_frame_views(msg: &[f32]) -> Option<(u64, Vec<&[f32]>)> {
    let (id, rest) = decode_frame_id(msg)?;
    let items = crate::comm::codec::unpack_views(rest)?;
    Some((id, items))
}

fn decode_frame(msg: &[f32]) -> Option<(u64, Vec<Vec<f32>>)> {
    let (id, views) = decode_frame_views(msg)?;
    Some((id, views.into_iter().map(|s| s.to_vec()).collect()))
}

/// Encode a `PredictBatch` frame: coalesced generator inputs under one id.
pub fn encode_predict_batch(id: u64, items: &[Vec<f32>]) -> Vec<f32> {
    let mut out = Vec::new();
    encode_frame_into(id, items, &mut out);
    out
}

/// Encode a `PredictBatch` frame into a reusable scratch (clears `out`):
/// the hot relay path re-encodes every batch with zero steady-state
/// allocations, then converts once into a shared payload for the shard.
pub fn encode_predict_batch_into<S: AsRef<[f32]>>(id: u64, items: &[S], out: &mut Vec<f32>) {
    encode_frame_into(id, items, out)
}

/// Decode a `PredictBatch` frame. `None` on malformed input.
pub fn decode_predict_batch(msg: &[f32]) -> Option<(u64, Vec<Vec<f32>>)> {
    decode_frame(msg)
}

/// Borrowed-view decode of a `PredictBatch` frame: items are subslices of
/// `msg`, so validation and relay never materialize an owned item list.
/// Accepts/rejects exactly like [`decode_predict_batch`].
pub fn decode_predict_batch_views(msg: &[f32]) -> Option<(u64, Vec<&[f32]>)> {
    decode_frame_views(msg)
}

/// Encode a `PredictBatchResult` frame: one output per batched item, in
/// batch order, echoing the request id.
pub fn encode_predict_batch_result(id: u64, outputs: &[Vec<f32>]) -> Vec<f32> {
    let mut out = Vec::new();
    encode_frame_into(id, outputs, &mut out);
    out
}

/// Encode a `PredictBatchResult` frame into a reusable scratch (clears
/// `out`); see [`encode_predict_batch_into`].
pub fn encode_predict_batch_result_into<S: AsRef<[f32]>>(
    id: u64,
    outputs: &[S],
    out: &mut Vec<f32>,
) {
    encode_frame_into(id, outputs, out)
}

/// Decode a `PredictBatchResult` frame. `None` on malformed input.
pub fn decode_predict_batch_result(msg: &[f32]) -> Option<(u64, Vec<Vec<f32>>)> {
    decode_frame(msg)
}

/// Borrowed-view decode of a `PredictBatchResult` frame; see
/// [`decode_predict_batch_views`].
pub fn decode_predict_batch_result_views(msg: &[f32]) -> Option<(u64, Vec<&[f32]>)> {
    decode_frame_views(msg)
}

// ---------------------------------------------------------------------------
// Flat-data-plane frame codecs (uniform batches, zero per-row work)
// ---------------------------------------------------------------------------

use crate::comm::bus::Payload;
use crate::data::batch::{BatchView, PayloadBatch, RowBlock, SharedRows};

fn decode_frame_rows(msg: &[f32]) -> Option<(u64, BatchView<'_>)> {
    let (id, rest) = decode_frame_id(msg)?;
    Some((id, crate::comm::codec::unpack_batch_view(rest)?))
}

/// Decode a `PredictBatch` frame whose items all share one width as a
/// strided [`BatchView`] over `msg` — **zero allocations**. Returns `None`
/// on malformed input *or* ragged item widths; callers fall back to
/// [`decode_predict_batch_views`] for the ragged case.
pub fn decode_predict_batch_rows(msg: &[f32]) -> Option<(u64, BatchView<'_>)> {
    decode_frame_rows(msg)
}

/// Flat-batch decode of a `PredictBatchResult` frame; see
/// [`decode_predict_batch_rows`].
pub fn decode_predict_batch_result_rows(msg: &[f32]) -> Option<(u64, BatchView<'_>)> {
    decode_frame_rows(msg)
}

/// Payload-retaining decode of a uniform `PredictBatchResult` frame: the
/// rows region is returned as a [`PayloadBatch`] — a zero-copy slice of the
/// received payload — so a committee reply can be held by refcount until
/// the whole batch reduces, without re-boxing any row.
pub fn decode_predict_batch_result_shared(msg: &Payload) -> Option<(u64, PayloadBatch)> {
    let (id, rest) = decode_frame_id(msg)?;
    let (rows, width, start) = crate::comm::codec::unpack_uniform(rest)?;
    // `rest` starts 2 values into the frame
    let data_start = 2 + start;
    let pb = PayloadBatch::from_payload(msg.slice(data_start..msg.len()), rows, width)?;
    Some((id, pb))
}

/// Payload-retaining decode of a **ragged-capable** `PredictBatchResult`
/// frame: row bounds parse from the packed header and the data section is
/// sliced out of the received payload as a [`SharedRows`] — committee
/// replies of any shape are held by refcount until reduction, with no
/// owned per-row copies (the uniform fast path stays
/// [`decode_predict_batch_result_shared`]).
pub fn decode_predict_batch_result_shared_rows(msg: &Payload) -> Option<(u64, SharedRows)> {
    let (id, rest) = decode_frame_id(msg)?;
    let (ends, start) = crate::comm::codec::unpack_row_ends(rest)?;
    // `rest` starts 2 values into the frame
    let data_start = 2 + start;
    let rows = SharedRows::from_payload_ends(msg.slice(data_start..msg.len()), ends)?;
    Some((id, rows))
}

fn encode_frame_rows_into(id: u64, batch: &BatchView<'_>, out: &mut Vec<f32>) {
    push_frame_id(id, out);
    crate::comm::codec::pack_batch_into(batch, out);
}

/// Encode a `PredictBatch` frame from a uniform batch (clears `out`) —
/// wire-identical to [`encode_predict_batch`] over the batch's rows, but
/// the data section is a single `memcpy`.
pub fn encode_predict_batch_rows_into(id: u64, batch: &BatchView<'_>, out: &mut Vec<f32>) {
    encode_frame_rows_into(id, batch, out)
}

/// Encode a `PredictBatchResult` frame from a uniform batch (clears `out`).
pub fn encode_predict_batch_result_rows_into(id: u64, batch: &BatchView<'_>, out: &mut Vec<f32>) {
    encode_frame_rows_into(id, batch, out)
}

/// Encode a `PredictBatch` frame from a contiguous (possibly ragged)
/// [`RowBlock`] (clears `out`) — the scheduler's dispatch path.
pub fn encode_predict_batch_block_into(id: u64, rows: &RowBlock, out: &mut Vec<f32>) {
    push_frame_id(id, out);
    crate::comm::codec::pack_rows_into_buf(rows, out);
}

/// Encode a `PredictBatchResult` frame from a contiguous (possibly ragged)
/// [`RowBlock`] (clears `out`) — the prediction host's reply path for
/// `Model::predict_batch` output.
pub fn encode_predict_batch_result_block_into(id: u64, rows: &RowBlock, out: &mut Vec<f32>) {
    push_frame_id(id, out);
    crate::comm::codec::pack_rows_into_buf(rows, out);
}

// ---------------------------------------------------------------------------
// Oracle-plane frames (batched oracle mode, green flow)
// ---------------------------------------------------------------------------
//
// `OracleBatch` (Manager → oracle) reuses the `PredictBatch` layout:
// `[id_hi, id_lo, pack of the input list]`. `OracleBatchResult` (oracle →
// Manager) carries interleaved `(input, label)` pairs under the same id
// header: `[id_hi, id_lo, pack of 2n parts x0 y0 x1 y1 ...]` — the packed
// section is byte-identical to `codec::pack_datapoints` over the pairs, so
// the Manager ingests it with the same borrowed-pair decoder
// (`codec::decode_train_block_views`) the training plane uses.

use crate::data::batch::DatapointView;

/// Encode an `OracleBatch` frame from the scheduler's staged input rows
/// (clears `out`) — wire-identical to a `PredictBatch` frame over the same
/// rows.
pub fn encode_oracle_batch_block_into(id: u64, rows: &RowBlock, out: &mut Vec<f32>) {
    push_frame_id(id, out);
    crate::comm::codec::pack_rows_into_buf(rows, out);
}

/// Flat decode of an `OracleBatch` frame: uniform-width inputs parse as a
/// strided [`BatchView`] over `msg` with zero allocations. `None` on
/// malformed input *or* ragged widths (fall back to
/// [`decode_oracle_batch_views`]).
pub fn decode_oracle_batch_rows(msg: &[f32]) -> Option<(u64, BatchView<'_>)> {
    decode_frame_rows(msg)
}

/// Borrowed-view decode of an `OracleBatch` frame (ragged-capable): inputs
/// are subslices of `msg`.
pub fn decode_oracle_batch_views(msg: &[f32]) -> Option<(u64, Vec<&[f32]>)> {
    decode_frame_views(msg)
}

/// Just the 48-bit id of an `OracleBatch` frame, even when the item
/// section is undecodable. The oracle host uses this to echo an *empty*
/// result for a malformed batch, so the Manager's scheduler always frees
/// the in-flight slot — a bad frame costs its labels, never green-flow
/// liveness.
pub fn decode_oracle_batch_id(msg: &[f32]) -> Option<u64> {
    decode_frame_id(msg).map(|(id, _)| id)
}

/// Encode an `OracleBatchResult` frame (clears `out`): `inputs[i]` pairs
/// with `labels.row(i)`, in batch order. The packed section is
/// byte-identical to `codec::pack_datapoints` over the same pairs
/// (property-tested), so per-label and batched labels interoperate with one
/// pair decoder.
pub fn encode_oracle_batch_result_into(
    id: u64,
    inputs: &[&[f32]],
    labels: &RowBlock,
    out: &mut Vec<f32>,
) {
    assert_eq!(inputs.len(), labels.len(), "one label row per batched input");
    const MAX_LEN: usize = crate::comm::codec::MAX_LEN;
    assert!(2 * inputs.len() < MAX_LEN, "too many parts");
    push_frame_id(id, out);
    out.push((2 * inputs.len()) as f32);
    for (i, x) in inputs.iter().enumerate() {
        let y = labels.row(i);
        assert!(x.len() < MAX_LEN && y.len() < MAX_LEN, "part too long for f32 header");
        out.push(x.len() as f32);
        out.push(y.len() as f32);
    }
    for (i, x) in inputs.iter().enumerate() {
        out.extend_from_slice(x);
        out.extend_from_slice(labels.row(i));
    }
}

/// Encode an `OracleBatchResult` frame straight from the decoded input
/// view and the label block (clears `out`) — byte-identical to
/// [`encode_oracle_batch_result_into`] over the same pairs, with no
/// per-row adapter list: the oracle host's uniform reply path is
/// allocation-free beyond the labels the oracle itself staged.
pub fn encode_oracle_batch_result_rows_into(
    id: u64,
    inputs: &BatchView<'_>,
    labels: &RowBlock,
    out: &mut Vec<f32>,
) {
    assert_eq!(inputs.rows(), labels.len(), "one label row per batched input");
    const MAX_LEN: usize = crate::comm::codec::MAX_LEN;
    let n = inputs.rows();
    let w = inputs.width();
    assert!(2 * n < MAX_LEN, "too many parts");
    assert!(w < MAX_LEN, "part too long for f32 header");
    push_frame_id(id, out);
    out.push((2 * n) as f32);
    for i in 0..n {
        let y = labels.row(i);
        assert!(y.len() < MAX_LEN, "part too long for f32 header");
        out.push(w as f32);
        out.push(y.len() as f32);
    }
    for i in 0..n {
        out.extend_from_slice(inputs.row(i));
        out.extend_from_slice(labels.row(i));
    }
}

/// Decode an `OracleBatchResult` frame into its id and a borrowed
/// [`DatapointView`] over `msg` — one bounds-list allocation total, no
/// per-pair boxing. Accepts and rejects the packed section exactly like
/// `codec::decode_train_block_views`.
pub fn decode_oracle_batch_result_views(msg: &[f32]) -> Option<(u64, DatapointView<'_>)> {
    let (id, rest) = decode_frame_id(msg)?;
    Some((id, crate::comm::codec::decode_train_block_views(rest)?))
}

// ---------------------------------------------------------------------------
// Labels-only oracle results (TAG_ORACLE_LABELS)
// ---------------------------------------------------------------------------
//
// The Manager already holds every input it dispatched (it staged the batch),
// so echoing inputs back in the result frame is pure wire waste. An
// `OracleLabels` frame ships only the label rows, in dispatch order, under
// the echoed id: `[id_hi, id_lo, pack of label rows]` — the exact
// `PredictBatchResult` layout, so all existing frame validation applies.

/// Encode an `OracleLabels` frame from the oracle's staged label rows
/// (clears `out`): `labels.row(i)` answers input `i` of the batch.
pub fn encode_oracle_labels_into(id: u64, labels: &RowBlock, out: &mut Vec<f32>) {
    push_frame_id(id, out);
    crate::comm::codec::pack_rows_into_buf(labels, out);
}

/// Borrowed-view decode of an `OracleLabels` frame (ragged-capable): label
/// rows are subslices of `msg`, in dispatch order. `None` on malformed
/// input.
pub fn decode_oracle_labels_views(msg: &[f32]) -> Option<(u64, Vec<&[f32]>)> {
    decode_frame_views(msg)
}

/// Flat decode of a uniform `OracleLabels` frame as a strided
/// [`BatchView`] — zero allocations; `None` on malformed input or ragged
/// label widths (fall back to [`decode_oracle_labels_views`]).
pub fn decode_oracle_labels_rows(msg: &[f32]) -> Option<(u64, BatchView<'_>)> {
    decode_frame_rows(msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_encoding_roundtrip() {
        let enc = encode_gen(true, &[1.0, 2.0]);
        let (stop, data) = decode_gen(&enc);
        assert!(stop);
        assert_eq!(data, &[1.0, 2.0]);
        let enc = encode_gen(false, &[]);
        let (stop, data) = decode_gen(&enc);
        assert!(!stop);
        assert!(data.is_empty());
    }

    #[test]
    fn batch_frame_roundtrip() {
        let items = vec![vec![1.0, 2.0], vec![], vec![3.0]];
        let enc = encode_predict_batch(7, &items);
        assert_eq!(decode_predict_batch(&enc), Some((7, items.clone())));
        let enc = encode_predict_batch_result((1 << 30) + 5, &items);
        assert_eq!(decode_predict_batch_result(&enc), Some(((1 << 30) + 5, items)));
        // empty batch
        let enc = encode_predict_batch(0, &[]);
        assert_eq!(decode_predict_batch(&enc), Some((0, vec![])));
    }

    #[test]
    fn batch_frame_views_match_owned_decode() {
        let items = vec![vec![1.0, 2.0], vec![], vec![3.0]];
        let enc = encode_predict_batch(7, &items);
        let (id, views) = decode_predict_batch_views(&enc).unwrap();
        assert_eq!(id, 7);
        assert_eq!(views, items.iter().map(|v| v.as_slice()).collect::<Vec<_>>());
        let (id2, views2) = decode_predict_batch_result_views(&enc).unwrap();
        assert_eq!((id2, views2.len()), (7, 3));
        // scratch encoders clear and produce identical bytes
        let mut scratch = vec![9.9f32; 3];
        encode_predict_batch_into(7, &items, &mut scratch);
        assert_eq!(scratch, enc);
        encode_predict_batch_result_into(7, &items, &mut scratch);
        assert_eq!(scratch, enc);
    }

    #[test]
    fn flat_frame_codec_interops_with_nested() {
        use crate::data::batch::Batch;
        let items = vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let nested_enc = encode_predict_batch(9, &items);
        // flat decode of a nested-encoded frame
        let (id, view) = decode_predict_batch_rows(&nested_enc).unwrap();
        assert_eq!((id, view.rows(), view.width()), (9, 3, 2));
        assert_eq!(view.row(2), &[5.0, 6.0]);
        // flat encode produces identical wire bytes
        let batch = Batch::from_rows(&items).unwrap();
        let mut flat_enc = vec![0.0f32; 2]; // must be cleared
        encode_predict_batch_rows_into(9, &batch.view(), &mut flat_enc);
        assert_eq!(flat_enc, nested_enc);
        encode_predict_batch_result_rows_into(9, &batch.view(), &mut flat_enc);
        assert_eq!(flat_enc, nested_enc);
        let rb = crate::data::batch::RowBlock::from_rows(&items);
        encode_predict_batch_block_into(9, &rb, &mut flat_enc);
        assert_eq!(flat_enc, nested_enc);
        // result-rows decode agrees
        let (id2, view2) = decode_predict_batch_result_rows(&nested_enc).unwrap();
        assert_eq!((id2, view2.rows()), (9, 3));
    }

    #[test]
    fn flat_frame_decode_rejects_ragged_and_truncated() {
        let ragged = vec![vec![1.0, 2.0], vec![3.0]];
        let enc = encode_predict_batch(1, &ragged);
        assert!(decode_predict_batch(&enc).is_some(), "nested accepts ragged");
        assert!(decode_predict_batch_rows(&enc).is_none(), "flat rejects ragged");
        let uniform = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        let enc = encode_predict_batch(1, &uniform);
        assert!(decode_predict_batch_rows(&enc[..enc.len() - 1]).is_none());
        assert!(decode_predict_batch_rows(&[]).is_none());
        // empty batch is uniform
        let empty = encode_predict_batch(5, &[]);
        let (id, view) = decode_predict_batch_rows(&empty).unwrap();
        assert_eq!((id, view.rows()), (5, 0));
    }

    #[test]
    fn shared_result_decode_slices_payload() {
        use crate::comm::bus::Payload;
        let items = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        let p = Payload::from(encode_predict_batch_result(3, &items));
        let (id, pb) = decode_predict_batch_result_shared(&p).unwrap();
        assert_eq!((id, pb.rows(), pb.width()), (3, 2, 2));
        assert_eq!(pb.view().row(1), &[3.0, 4.0]);
        // the rows region shares the frame payload's buffer
        assert!(p.shared_handles() >= 2);
        // ragged/truncated payloads reject
        let ragged = Payload::from(encode_predict_batch_result(3, &[vec![1.0], vec![2.0, 3.0]]));
        assert!(decode_predict_batch_result_shared(&ragged).is_none());
    }

    #[test]
    fn oracle_batch_frame_matches_predict_batch_layout() {
        let items = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        let rb = RowBlock::from_rows(&items);
        let mut enc = vec![9.9f32; 3]; // must be cleared
        encode_oracle_batch_block_into(11, &rb, &mut enc);
        assert_eq!(enc, encode_predict_batch(11, &items), "same frame layout");
        let (id, view) = decode_oracle_batch_rows(&enc).unwrap();
        assert_eq!((id, view.rows(), view.width()), (11, 2, 2));
        let (id2, views) = decode_oracle_batch_views(&enc).unwrap();
        assert_eq!((id2, views.len()), (11, 2));
        assert_eq!(views[1], &[3.0, 4.0]);
        // ragged inputs reject the flat decode, survive the view decode
        let ragged = RowBlock::from_rows(&[vec![1.0f32], vec![2.0, 3.0]]);
        encode_oracle_batch_block_into(1, &ragged, &mut enc);
        assert!(decode_oracle_batch_rows(&enc).is_none());
        assert_eq!(decode_oracle_batch_views(&enc).unwrap().1.len(), 2);
    }

    #[test]
    fn oracle_batch_result_packed_section_matches_pack_datapoints() {
        let pairs = vec![
            (vec![1.0f32, 2.0], vec![0.5f32]),
            (vec![3.0], vec![0.25, 0.75]),
            (vec![], vec![9.0]),
        ];
        let inputs: Vec<&[f32]> = pairs.iter().map(|(x, _)| x.as_slice()).collect();
        let labels = RowBlock::from_rows(&pairs.iter().map(|(_, y)| y.clone()).collect::<Vec<_>>());
        let mut enc = vec![1.0f32; 2]; // must be cleared
        encode_oracle_batch_result_into(5, &inputs, &labels, &mut enc);
        // frame = id header + the legacy datapoint encoding, byte for byte
        assert_eq!(&enc[2..], crate::comm::codec::pack_datapoints(&pairs).as_slice());
        let (id, view) = decode_oracle_batch_result_views(&enc).unwrap();
        assert_eq!(id, 5);
        assert_eq!(view.to_nested(), pairs);
        // the view-typed encoder (uniform inputs) writes identical bytes
        let uniform = vec![(vec![1.0f32, 2.0], vec![0.5f32]), (vec![3.0, 4.0], vec![0.25, 0.75])];
        let u_inputs: Vec<&[f32]> = uniform.iter().map(|(x, _)| x.as_slice()).collect();
        let u_labels =
            RowBlock::from_rows(&uniform.iter().map(|(_, y)| y.clone()).collect::<Vec<_>>());
        let mut from_slices = Vec::new();
        encode_oracle_batch_result_into(9, &u_inputs, &u_labels, &mut from_slices);
        let u_block = crate::data::batch::Batch::from_rows(
            &uniform.iter().map(|(x, _)| x.clone()).collect::<Vec<_>>(),
        )
        .unwrap();
        let mut from_view = vec![4.0f32]; // must be cleared
        encode_oracle_batch_result_rows_into(9, &u_block.view(), &u_labels, &mut from_view);
        assert_eq!(from_view, from_slices);
        // truncation / trailing garbage / odd-part frames reject
        assert!(decode_oracle_batch_result_views(&enc[..enc.len() - 1]).is_none());
        let mut garbage = enc.clone();
        garbage.push(7.0);
        assert!(decode_oracle_batch_result_views(&garbage).is_none());
        assert!(decode_oracle_batch_result_views(&[]).is_none());
        // empty batch result round-trips
        let empty = RowBlock::new();
        encode_oracle_batch_result_into(0, &[], &empty, &mut enc);
        assert_eq!(decode_oracle_batch_result_views(&enc).unwrap().1.len(), 0);
    }

    #[test]
    fn oracle_labels_frame_roundtrip() {
        let labels = RowBlock::from_rows(&[vec![0.5f32, 1.5], vec![2.5, 3.5], vec![4.5, 5.5]]);
        let mut enc = vec![9.9f32; 3]; // must be cleared
        encode_oracle_labels_into(13, &labels, &mut enc);
        // same frame layout as a PredictBatchResult over the label rows
        assert_eq!(
            enc,
            encode_predict_batch_result(
                13,
                &[vec![0.5, 1.5], vec![2.5, 3.5], vec![4.5, 5.5]]
            )
        );
        let (id, views) = decode_oracle_labels_views(&enc).unwrap();
        assert_eq!((id, views.len()), (13, 3));
        assert_eq!(views[2], &[4.5, 5.5]);
        let (id2, rows) = decode_oracle_labels_rows(&enc).unwrap();
        assert_eq!((id2, rows.rows(), rows.width()), (13, 3, 2));
        // ragged labels survive the view decode, reject the flat decode
        let ragged = RowBlock::from_rows(&[vec![1.0f32], vec![2.0, 3.0]]);
        encode_oracle_labels_into(1, &ragged, &mut enc);
        assert!(decode_oracle_labels_rows(&enc).is_none());
        assert_eq!(decode_oracle_labels_views(&enc).unwrap().1.len(), 2);
        // an empty echo (malformed-batch reply) round-trips and keeps its id
        encode_oracle_labels_into(42, &RowBlock::new(), &mut enc);
        let (id3, views3) = decode_oracle_labels_views(&enc).unwrap();
        assert_eq!((id3, views3.len()), (42, 0));
        // truncation rejects
        encode_oracle_labels_into(7, &labels, &mut enc);
        assert!(decode_oracle_labels_views(&enc[..enc.len() - 1]).is_none());
    }

    #[test]
    fn shared_rows_decode_handles_ragged_results() {
        use crate::comm::bus::Payload;
        let items = vec![vec![1.0f32, 2.0], vec![3.0], vec![], vec![4.0, 5.0, 6.0]];
        let p = Payload::from(encode_predict_batch_result(21, &items));
        let (id, rows) = decode_predict_batch_result_shared_rows(&p).unwrap();
        assert_eq!((id, rows.len()), (21, 4));
        for (i, item) in items.iter().enumerate() {
            assert_eq!(rows.row(i), item.as_slice());
        }
        // the rows region shares the frame payload's buffer
        assert!(p.shared_handles() >= 2);
        // truncated frames reject
        let full: Vec<f32> = p.as_slice().to_vec();
        let trunc = Payload::from(&full[..full.len() - 1]);
        assert!(decode_predict_batch_result_shared_rows(&trunc).is_none());
    }

    #[test]
    fn gen_encode_into_clears_scratch() {
        let mut scratch = vec![7.0f32; 5];
        encode_gen_into(true, &[1.0, 2.0], &mut scratch);
        assert_eq!(scratch, encode_gen(true, &[1.0, 2.0]));
    }

    #[test]
    fn batch_frame_rejects_malformed() {
        assert!(decode_predict_batch(&[]).is_none());
        assert!(decode_predict_batch(&[0.0]).is_none());
        // non-integer id halves
        assert!(decode_predict_batch(&[0.5, 0.0, 0.0]).is_none());
        // negative id halves
        assert!(decode_predict_batch(&[-1.0, 0.0, 0.0]).is_none());
        // truncated payload
        let enc = encode_predict_batch(3, &[vec![1.0, 2.0]]);
        assert!(decode_predict_batch(&enc[..enc.len() - 1]).is_none());
    }

    #[test]
    fn tags_are_distinct() {
        let tags = [
            TAG_GEN_TO_PRED, TAG_PRED_IN, TAG_PRED_OUT, TAG_GENE_IN, TAG_GEN_SIZE,
            TAG_PRED_BATCH, TAG_PRED_BATCH_RESULT,
            TAG_ORCL_SELECT, TAG_TO_ORACLE, TAG_ORACLE_RESULT,
            TAG_ORACLE_BATCH, TAG_ORACLE_BATCH_RESULT, TAG_ORACLE_LABELS,
            TAG_TRAIN_DATA, TAG_WEIGHTS, TAG_RETRAIN_DONE,
            TAG_RESCORE_REQ, TAG_RESCORE_RESP, TAG_STOP, TAG_SHUTDOWN, TAG_RANK_DOWN,
        ];
        let mut sorted = tags.to_vec();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), tags.len());
    }
}
