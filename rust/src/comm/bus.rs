//! Rank endpoints, tagged matching, collectives, and injectable latency.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A tagged message between ranks.
#[derive(Debug, Clone)]
pub struct Message {
    pub src: usize,
    pub tag: u32,
    pub data: Vec<f32>,
    /// Simulated arrival time (send time + world latency).
    ready_at: Instant,
}

/// Error returned by receive operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecvError {
    Timeout,
    /// All senders dropped — the world is shutting down.
    Disconnected,
}

impl std::fmt::Display for RecvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RecvError::Timeout => write!(f, "receive timed out"),
            RecvError::Disconnected => write!(f, "world disconnected"),
        }
    }
}

impl std::error::Error for RecvError {}

/// Aggregate transport statistics (for the comm-overhead bench).
#[derive(Debug, Default)]
pub struct WorldStats {
    pub messages: AtomicU64,
    pub payload_f32s: AtomicU64,
}

impl WorldStats {
    pub fn messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }
    pub fn payload_bytes(&self) -> u64 {
        self.payload_f32s.load(Ordering::Relaxed) * 4
    }
}

/// A communicator over `n` ranks.
pub struct World {
    senders: Vec<Sender<Message>>,
    receivers: Vec<Option<Receiver<Message>>>,
    latency: Duration,
    stats: Arc<WorldStats>,
}

impl World {
    /// Create a world with `n` ranks and zero injected latency.
    pub fn new(n: usize) -> Self {
        Self::with_latency(n, Duration::ZERO)
    }

    /// Create a world where every message arrives `latency` after sending.
    pub fn with_latency(n: usize, latency: Duration) -> Self {
        let mut senders = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(Some(rx));
        }
        World { senders, receivers, latency, stats: Arc::new(WorldStats::default()) }
    }

    pub fn size(&self) -> usize {
        self.senders.len()
    }

    pub fn stats(&self) -> Arc<WorldStats> {
        Arc::clone(&self.stats)
    }

    /// Take rank `rank`'s endpoint. Each endpoint can be taken exactly once
    /// and moved into that kernel's host thread.
    pub fn endpoint(&mut self, rank: usize) -> Endpoint {
        let rx = self.receivers[rank].take().expect("endpoint already taken");
        let senders = self
            .senders
            .iter()
            .enumerate()
            .map(|(i, s)| if i == rank { None } else { Some(s.clone()) })
            .collect();
        Endpoint {
            rank,
            rx,
            senders,
            pending: VecDeque::new(),
            latency: self.latency,
            stats: Arc::clone(&self.stats),
        }
    }

    /// Take all endpoints in rank order (convenience for spawning).
    pub fn endpoints(&mut self) -> Vec<Endpoint> {
        (0..self.size()).map(|r| self.endpoint(r)).collect()
    }
}

/// One rank's communication handle.
pub struct Endpoint {
    rank: usize,
    rx: Receiver<Message>,
    /// Senders to every rank; the slot for our own rank is None so that
    /// channel disconnection (all peers + World dropped) is observable.
    senders: Vec<Option<Sender<Message>>>,
    /// Received-but-unmatched messages (MPI-style out-of-order matching).
    pending: VecDeque<Message>,
    latency: Duration,
    stats: Arc<WorldStats>,
}

/// Matcher for receives: exact source or any.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Src {
    Any,
    Rank(usize),
}

impl Src {
    fn matches(&self, src: usize) -> bool {
        match self {
            Src::Any => true,
            Src::Rank(r) => *r == src,
        }
    }
}

impl Endpoint {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world_size(&self) -> usize {
        self.senders.len()
    }

    /// Point-to-point send. Never blocks (channels are unbounded); the
    /// injected latency delays *visibility*, not the sender.
    pub fn send(&self, dst: usize, tag: u32, data: Vec<f32>) {
        self.stats.messages.fetch_add(1, Ordering::Relaxed);
        self.stats.payload_f32s.fetch_add(data.len() as u64, Ordering::Relaxed);
        // A send can fail only if the destination endpoint was dropped during
        // shutdown; that's benign by design (drain discipline). Sends to
        // self are not part of the protocol and are dropped.
        if let Some(tx) = &self.senders[dst] {
            let _ = tx.send(Message {
                src: self.rank,
                tag,
                data,
                ready_at: Instant::now() + self.latency,
            });
        }
    }

    /// Broadcast the same payload to every rank in `dsts`.
    pub fn bcast(&self, dsts: &[usize], tag: u32, data: &[f32]) {
        for &d in dsts {
            self.send(d, tag, data.to_vec());
        }
    }

    /// Scatter one payload per destination (lengths may differ).
    pub fn scatter(&self, dsts: &[usize], tag: u32, payloads: Vec<Vec<f32>>) {
        assert_eq!(dsts.len(), payloads.len(), "scatter arity mismatch");
        for (&d, p) in dsts.iter().zip(payloads) {
            self.send(d, tag, p);
        }
    }

    fn pop_pending(&mut self, src: Src, tag: u32) -> Option<Message> {
        self.pop_pending_tags(src, &[tag])
    }

    fn pop_pending_tags(&mut self, src: Src, tags: &[u32]) -> Option<Message> {
        let now = Instant::now();
        let idx = self
            .pending
            .iter()
            .position(|m| tags.contains(&m.tag) && src.matches(m.src) && m.ready_at <= now)?;
        self.pending.remove(idx)
    }

    /// Non-blocking check whether a matching message is available
    /// (the paper's `req_data.Test()`).
    pub fn probe(&mut self, src: Src, tag: u32) -> bool {
        self.drain_channel();
        let now = Instant::now();
        self.pending
            .iter()
            .any(|m| m.tag == tag && src.matches(m.src) && m.ready_at <= now)
    }

    fn drain_channel(&mut self) {
        while let Ok(m) = self.rx.try_recv() {
            self.pending.push_back(m);
        }
    }

    /// Blocking receive with timeout and MPI-style (src, tag) matching.
    ///
    /// Hot-path note (§Perf): before parking on the OS channel we spin a few
    /// times with `yield_now`. On a single-core host a blocked `recv` costs
    /// a full scheduler round-trip (~0.4 ms/hop measured); yielding lets the
    /// producer run immediately and cuts the exchange round-trip ~5x.
    pub fn recv_timeout(
        &mut self,
        src: Src,
        tag: u32,
        timeout: Duration,
    ) -> Result<Message, RecvError> {
        self.recv_timeout_tags(src, &[tag], timeout)
    }

    /// Blocking receive matching *any* of `tags` (first available wins;
    /// `Message::tag` tells the caller which). Used by hosts that serve
    /// multiple request kinds on one loop — e.g. predictors serving both
    /// lockstep broadcasts and batch frames.
    pub fn recv_timeout_tags(
        &mut self,
        src: Src,
        tags: &[u32],
        timeout: Duration,
    ) -> Result<Message, RecvError> {
        // short cooperative spin before blocking
        for _ in 0..8 {
            self.drain_channel();
            if let Some(m) = self.pop_pending_tags(src, tags) {
                return Ok(m);
            }
            std::thread::yield_now();
        }
        let deadline = Instant::now() + timeout;
        loop {
            self.drain_channel();
            if let Some(m) = self.pop_pending_tags(src, tags) {
                return Ok(m);
            }
            // If a matching message exists but its simulated arrival is in
            // the future, sleep until it is ready (bounded by the deadline).
            let next_ready = self
                .pending
                .iter()
                .filter(|m| tags.contains(&m.tag) && src.matches(m.src))
                .map(|m| m.ready_at)
                .min();
            let now = Instant::now();
            if now >= deadline {
                return Err(RecvError::Timeout);
            }
            let wait_until = next_ready.unwrap_or(deadline).min(deadline);
            if wait_until > now {
                match self.rx.recv_timeout(wait_until - now) {
                    Ok(m) => self.pending.push_back(m),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => {
                        // Drain pending before giving up.
                        if self
                            .pending
                            .iter()
                            .any(|m| tags.contains(&m.tag) && src.matches(m.src))
                        {
                            continue;
                        }
                        return Err(RecvError::Disconnected);
                    }
                }
            }
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&mut self, src: Src, tag: u32) -> Option<Message> {
        self.drain_channel();
        self.pop_pending(src, tag)
    }

    /// Receive the *latest* matching message, discarding older ones
    /// (used for weight updates where only the newest matters).
    pub fn recv_latest(&mut self, src: Src, tag: u32) -> Option<Message> {
        let mut last = None;
        while let Some(m) = self.try_recv(src, tag) {
            last = Some(m);
        }
        last
    }

    /// Gather one message from every rank in `srcs` (any arrival order),
    /// returning payloads ordered like `srcs`.
    pub fn gather(
        &mut self,
        srcs: &[usize],
        tag: u32,
        timeout: Duration,
    ) -> Result<Vec<Vec<f32>>, RecvError> {
        let deadline = Instant::now() + timeout;
        let mut slots: Vec<Option<Vec<f32>>> = vec![None; srcs.len()];
        let mut remaining = srcs.len();
        while remaining > 0 {
            let now = Instant::now();
            if now >= deadline {
                return Err(RecvError::Timeout);
            }
            let m = self.recv_timeout(Src::Any, tag, deadline - now)?;
            if let Some(i) = srcs.iter().position(|&s| s == m.src) {
                if slots[i].is_none() {
                    slots[i] = Some(m.data);
                    remaining -= 1;
                } else {
                    // Duplicate from the same src (next iteration's message
                    // arriving early) — keep it for the next gather.
                    self.pending.push_back(m);
                    // Avoid busy-spinning on our own requeued message.
                    std::thread::sleep(Duration::from_micros(50));
                }
            }
        }
        Ok(slots.into_iter().map(|s| s.unwrap()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn point_to_point_roundtrip() {
        let mut w = World::new(2);
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        a.send(1, 7, vec![1.0, 2.0]);
        let m = b.recv_timeout(Src::Rank(0), 7, Duration::from_secs(1)).unwrap();
        assert_eq!(m.src, 0);
        assert_eq!(m.data, vec![1.0, 2.0]);
    }

    #[test]
    fn tag_matching_out_of_order() {
        let mut w = World::new(2);
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        a.send(1, 1, vec![1.0]);
        a.send(1, 2, vec![2.0]);
        // receive tag 2 first even though tag 1 arrived first
        let m2 = b.recv_timeout(Src::Rank(0), 2, Duration::from_secs(1)).unwrap();
        assert_eq!(m2.data, vec![2.0]);
        let m1 = b.recv_timeout(Src::Rank(0), 1, Duration::from_secs(1)).unwrap();
        assert_eq!(m1.data, vec![1.0]);
    }

    #[test]
    fn fifo_per_src_tag() {
        let mut w = World::new(2);
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        for i in 0..10 {
            a.send(1, 3, vec![i as f32]);
        }
        for i in 0..10 {
            let m = b.recv_timeout(Src::Rank(0), 3, Duration::from_secs(1)).unwrap();
            assert_eq!(m.data[0], i as f32);
        }
    }

    #[test]
    fn probe_is_nonblocking_test() {
        let mut w = World::new(2);
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        assert!(!b.probe(Src::Rank(0), 5));
        a.send(1, 5, vec![]);
        // drain into pending
        while !b.probe(Src::Rank(0), 5) {
            thread::yield_now();
        }
        assert!(b.try_recv(Src::Rank(0), 5).is_some());
        assert!(!b.probe(Src::Rank(0), 5));
    }

    #[test]
    fn multi_tag_recv_takes_first_available() {
        let mut w = World::new(2);
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        a.send(1, 5, vec![5.0]);
        a.send(1, 3, vec![3.0]);
        // arrival order wins across the tag set
        let m = b.recv_timeout_tags(Src::Rank(0), &[3, 5], Duration::from_secs(1)).unwrap();
        assert_eq!(m.tag, 5);
        let m = b.recv_timeout_tags(Src::Rank(0), &[3, 5], Duration::from_secs(1)).unwrap();
        assert_eq!(m.tag, 3);
        // non-listed tags don't match
        a.send(1, 9, vec![]);
        let r = b.recv_timeout_tags(Src::Rank(0), &[3, 5], Duration::from_millis(20));
        assert_eq!(r.unwrap_err(), RecvError::Timeout);
    }

    #[test]
    fn timeout_fires() {
        let mut w = World::new(2);
        let _a = w.endpoint(0);
        let mut b = w.endpoint(1);
        let r = b.recv_timeout(Src::Rank(0), 1, Duration::from_millis(20));
        assert_eq!(r.unwrap_err(), RecvError::Timeout);
    }

    #[test]
    fn disconnected_when_all_senders_drop() {
        let mut w = World::new(2);
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        drop(a);
        drop(w); // drops the stored sender clones too
        let r = b.recv_timeout(Src::Any, 1, Duration::from_secs(1));
        assert_eq!(r.unwrap_err(), RecvError::Disconnected);
    }

    #[test]
    fn gather_orders_by_src_list() {
        let mut w = World::new(4);
        let mut eps = w.endpoints();
        let e3 = eps.pop().unwrap();
        let e2 = eps.pop().unwrap();
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        // send in reverse rank order
        e3.send(0, 9, vec![3.0]);
        e2.send(0, 9, vec![2.0]);
        e1.send(0, 9, vec![1.0]);
        let got = e0.gather(&[1, 2, 3], 9, Duration::from_secs(1)).unwrap();
        assert_eq!(got, vec![vec![1.0], vec![2.0], vec![3.0]]);
    }

    #[test]
    fn gather_keeps_early_next_round_messages() {
        let mut w = World::new(2);
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        a.send(1, 9, vec![1.0]); // round 1
        a.send(1, 9, vec![2.0]); // round 2 arrives early
        let r1 = b.gather(&[0], 9, Duration::from_secs(1)).unwrap();
        assert_eq!(r1, vec![vec![1.0]]);
        let r2 = b.gather(&[0], 9, Duration::from_secs(1)).unwrap();
        assert_eq!(r2, vec![vec![2.0]]);
    }

    #[test]
    fn scatter_delivers_distinct_payloads() {
        let mut w = World::new(3);
        let mut eps = w.endpoints();
        let mut e2 = eps.pop().unwrap();
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        e0.scatter(&[1, 2], 4, vec![vec![1.0], vec![2.0]]);
        assert_eq!(e1.recv_timeout(Src::Rank(0), 4, Duration::from_secs(1)).unwrap().data, vec![1.0]);
        assert_eq!(e2.recv_timeout(Src::Rank(0), 4, Duration::from_secs(1)).unwrap().data, vec![2.0]);
    }

    #[test]
    fn bcast_same_payload() {
        let mut w = World::new(3);
        let mut eps = w.endpoints();
        let mut e2 = eps.pop().unwrap();
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        e0.bcast(&[1, 2], 6, &[5.0, 6.0]);
        for e in [&mut e1, &mut e2] {
            assert_eq!(e.recv_timeout(Src::Rank(0), 6, Duration::from_secs(1)).unwrap().data, vec![5.0, 6.0]);
        }
    }

    #[test]
    fn latency_delays_visibility_not_sender() {
        let mut w = World::with_latency(2, Duration::from_millis(40));
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        let t0 = Instant::now();
        a.send(1, 1, vec![1.0]);
        let send_cost = t0.elapsed();
        assert!(send_cost < Duration::from_millis(10), "sender blocked {send_cost:?}");
        let m = b.recv_timeout(Src::Rank(0), 1, Duration::from_secs(1)).unwrap();
        assert_eq!(m.data, vec![1.0]);
        assert!(t0.elapsed() >= Duration::from_millis(35), "latency not applied");
    }

    #[test]
    fn recv_latest_discards_stale() {
        let mut w = World::new(2);
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        a.send(1, 8, vec![1.0]);
        a.send(1, 8, vec![2.0]);
        a.send(1, 8, vec![3.0]);
        thread::sleep(Duration::from_millis(5));
        let m = b.recv_latest(Src::Rank(0), 8).unwrap();
        assert_eq!(m.data, vec![3.0]);
        assert!(b.try_recv(Src::Rank(0), 8).is_none());
    }

    #[test]
    fn stats_count_messages_and_bytes() {
        let mut w = World::new(2);
        let stats = w.stats();
        let a = w.endpoint(0);
        let mut _b = w.endpoint(1);
        a.send(1, 1, vec![0.0; 10]);
        a.send(1, 1, vec![0.0; 5]);
        assert_eq!(stats.messages(), 2);
        assert_eq!(stats.payload_bytes(), 60);
    }

    #[test]
    fn cross_thread_pingpong() {
        let mut w = World::new(2);
        let mut e0 = w.endpoint(0);
        let mut e1 = w.endpoint(1);
        let h = thread::spawn(move || {
            for _ in 0..100 {
                let m = e1.recv_timeout(Src::Rank(0), 1, Duration::from_secs(5)).unwrap();
                e1.send(0, 2, m.data);
            }
        });
        for i in 0..100 {
            e0.send(1, 1, vec![i as f32]);
            let m = e0.recv_timeout(Src::Rank(1), 2, Duration::from_secs(5)).unwrap();
            assert_eq!(m.data[0], i as f32);
        }
        h.join().unwrap();
    }
}
