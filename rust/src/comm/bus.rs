//! Rank endpoints, tagged matching, collectives, and injectable latency.
//!
//! ## Zero-copy payloads
//!
//! Every message carries a [`Payload`]: an `Arc<[f32]>`-backed, cheaply
//! clonable buffer. Sending a `Payload` (or `&Payload`) is a refcount bump —
//! the transport never copies the data. Sending owned/borrowed `f32` data
//! (`Vec<f32>`, `&[f32]`) converts it into shared storage exactly once at
//! the bus boundary; collectives ([`Endpoint::bcast`]) perform that
//! conversion once and then share, so fan-out cost is independent of the
//! destination count. [`WorldStats`] separates the *logical* traffic volume
//! (`payload_bytes`, which scales with destinations) from the *physical*
//! copy volume (`bytes_copied` / `payload_clones`, which does not).
//!
//! ## Indexed mailboxes
//!
//! Received-but-unmatched messages are held in per-tag mailboxes
//! (`HashMap<tag, VecDeque>`), so `recv(src, tag)` inspects only that tag's
//! queue instead of rescanning all queued traffic — O(1) amortized per
//! message for the common exact-tag case. Cross-tag arrival order (needed by
//! [`Endpoint::recv_timeout_tags`]) is preserved with a per-endpoint
//! sequence stamp assigned at mailbox insertion.

use std::collections::{HashMap, VecDeque};
use std::ops::Deref;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::comm::fault::{ArrivalAction, FaultPlan, FaultState};
use crate::comm::transport::{
    self, channel::ChannelWorld, shm::ShmWorld, Transport, TransportKind, TransportSender,
    TransportWorld,
};

/// A shared, immutable message payload: a range view into an `Arc<[f32]>`.
///
/// Cloning is a refcount bump; all reads go through `Deref<Target = [f32]>`.
/// Construction from owned or borrowed data copies once into shared storage
/// — after that the buffer can fan out to any number of destinations (or be
/// re-sent on a relay hop) without touching the heap. [`Payload::slice`]
/// carves sub-range views that share the same backing buffer, so scattering
/// the rows of one batch to many destinations is *n* refcount bumps over one
/// allocation. Everything above the bus treats payloads as immutable shared
/// buffers — which is exactly what lets the concrete transports slot in
/// underneath: [`crate::comm::transport::channel`] (the default `mpsc` bus),
/// [`crate::comm::transport::shm`] (lock-free per-rank-pair rings that hand
/// off buffer ownership), and [`crate::comm::transport::tcp`] (framed
/// sockets that serialize at the process boundary only).
#[derive(Debug, Clone)]
pub struct Payload {
    buf: Arc<[f32]>,
    start: usize,
    len: usize,
}

impl Payload {
    fn whole(buf: Arc<[f32]>) -> Self {
        let len = buf.len();
        Payload { buf, start: 0, len }
    }

    /// The empty payload (control messages). Cached in a `OnceLock` so
    /// zero-length sends never allocate a fresh `Arc`.
    pub fn empty() -> Self {
        static EMPTY: std::sync::OnceLock<Arc<[f32]>> = std::sync::OnceLock::new();
        Payload::whole(Arc::clone(EMPTY.get_or_init(|| Arc::from(Vec::new()))))
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.buf[self.start..self.start + self.len]
    }

    /// A sub-range view sharing this payload's backing buffer — no copy,
    /// just a refcount bump. Used to scatter the rows of one batch result
    /// payload to their originating generators.
    pub fn slice(&self, range: std::ops::Range<usize>) -> Payload {
        assert!(range.start <= range.end && range.end <= self.len, "payload slice out of range");
        Payload {
            buf: Arc::clone(&self.buf),
            start: self.start + range.start,
            len: range.end - range.start,
        }
    }

    /// Number of other live handles sharing this buffer (diagnostics).
    pub fn shared_handles(&self) -> usize {
        Arc::strong_count(&self.buf)
    }

    /// Identity of the viewed data: backing-buffer address plus view range.
    /// Two payloads with equal identity alias the same immutable values, so
    /// the identity is a valid cache key for derived state (the runtime's
    /// device-resident upload cache) for as long as a handle to the payload
    /// is held. The address is only meaningful while the `Arc` is alive —
    /// never dereference it, and never compare identities across a drop.
    pub fn ident(&self) -> PayloadId {
        PayloadId {
            addr: Arc::as_ptr(&self.buf) as *const f32 as usize,
            start: self.start,
            len: self.len,
        }
    }
}

/// Value identity of a [`Payload`] view (see [`Payload::ident`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PayloadId {
    addr: usize,
    start: usize,
    len: usize,
}

impl From<Vec<f32>> for Payload {
    fn from(v: Vec<f32>) -> Self {
        if v.is_empty() {
            return Payload::empty();
        }
        Payload::whole(Arc::from(v))
    }
}

impl From<&[f32]> for Payload {
    fn from(s: &[f32]) -> Self {
        if s.is_empty() {
            return Payload::empty();
        }
        Payload::whole(Arc::from(s))
    }
}

impl Deref for Payload {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        self.as_slice()
    }
}

impl AsRef<[f32]> for Payload {
    fn as_ref(&self) -> &[f32] {
        self.as_slice()
    }
}

impl PartialEq for Payload {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<[f32]> for Payload {
    fn eq(&self, other: &[f32]) -> bool {
        self.as_slice() == other
    }
}

impl PartialEq<Vec<f32>> for Payload {
    fn eq(&self, other: &Vec<f32>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<&[f32]> for Payload {
    fn eq(&self, other: &&[f32]) -> bool {
        self.as_slice() == *other
    }
}

/// Conversion into a [`Payload`] at the bus boundary, reporting whether the
/// conversion had to copy data into fresh shared storage. Already-shared
/// payloads convert for free; owned/borrowed data costs exactly one copy,
/// charged to [`WorldStats::bytes_copied`] by the sending endpoint.
pub trait IntoPayload {
    fn into_payload(self) -> (Payload, bool);
}

impl IntoPayload for Payload {
    fn into_payload(self) -> (Payload, bool) {
        (self, false)
    }
}

impl IntoPayload for &Payload {
    fn into_payload(self) -> (Payload, bool) {
        (self.clone(), false)
    }
}

impl IntoPayload for Vec<f32> {
    fn into_payload(self) -> (Payload, bool) {
        let copied = !self.is_empty(); // empty resolves to the cached payload
        (Payload::from(self), copied)
    }
}

impl IntoPayload for &[f32] {
    fn into_payload(self) -> (Payload, bool) {
        let copied = !self.is_empty();
        (Payload::from(self), copied)
    }
}

impl IntoPayload for &Vec<f32> {
    fn into_payload(self) -> (Payload, bool) {
        let copied = !self.is_empty();
        (Payload::from(self.as_slice()), copied)
    }
}

impl<const N: usize> IntoPayload for &[f32; N] {
    fn into_payload(self) -> (Payload, bool) {
        (Payload::from(&self[..]), true)
    }
}

/// File one drained batch of messages into a gather's slots: fill the
/// first message per listed source, *defer* an already-filled source's
/// early next-round traffic (callers reinject it via
/// [`Endpoint::requeue_front`] — oldest first, so per-(src, tag) FIFO is
/// preserved), and drop messages from unlisted sources (matching the
/// blocking matcher's behavior). Returns the number of newly filled slots.
///
/// Slots hold whole [`Message`]s (not just payloads) so an *aborted*
/// gather can requeue what it already consumed: dropping the filled
/// current-round messages while requeueing the deferred next-round ones
/// would leave the mailbox starting mid-stream — early next-round traffic
/// interleaved in place of the consumed round. This is the single
/// ordering-sensitive fill step shared by [`Endpoint::gather`] and the
/// host-side shutdown-polling gather.
pub fn fill_gather_slots(
    batch: Vec<Message>,
    srcs: &[usize],
    slots: &mut [Option<Message>],
    deferred: &mut Vec<Message>,
) -> usize {
    let mut filled = 0;
    for m in batch {
        if let Some(i) = srcs.iter().position(|&s| s == m.src) {
            if slots[i].is_none() {
                slots[i] = Some(m);
                filled += 1;
            } else {
                deferred.push(m);
            }
        }
    }
    filled
}

/// A tagged message between ranks.
#[derive(Debug, Clone)]
pub struct Message {
    pub src: usize,
    pub tag: u32,
    pub data: Payload,
    /// Simulated arrival time (send time + world latency). Monotonic per
    /// sender; the shm backend also uses it to merge its per-source rings
    /// back into global arrival order.
    pub(crate) ready_at: Instant,
    /// Mailbox arrival stamp (assigned by the receiving endpoint) so
    /// multi-tag receives preserve cross-tag arrival order.
    pub(crate) seq: u64,
}

/// Error returned by receive operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecvError {
    Timeout,
    /// All senders dropped — the world is shutting down.
    Disconnected,
}

impl std::fmt::Display for RecvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RecvError::Timeout => write!(f, "receive timed out"),
            RecvError::Disconnected => write!(f, "world disconnected"),
        }
    }
}

impl std::error::Error for RecvError {}

/// Aggregate transport statistics (for the comm-overhead bench).
///
/// `messages`/`payload_f32s` count *logical* traffic: every destination of a
/// broadcast counts its full payload. `payload_clones`/`bytes_copied`
/// count *physical* work: payload buffers the transport had to materialize.
/// A broadcast of one shared [`Payload`] to `n` ranks is `n` messages and
/// `n × len × 4` logical bytes, but zero clones and zero copied bytes.
#[derive(Debug, Default)]
pub struct WorldStats {
    pub messages: AtomicU64,
    pub payload_f32s: AtomicU64,
    /// Payload buffers materialized (deep-copied) by the transport.
    pub payload_clones: AtomicU64,
    /// Bytes physically copied into shared storage by the transport.
    pub bytes_copied: AtomicU64,
    /// Sends that found the destination's endpoint already dropped (its
    /// host dead or shut down): the message was lost, and the sender was
    /// told so ([`Endpoint::send`] returned `false`).
    pub dead_letters: AtomicU64,
}

impl WorldStats {
    pub fn messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }
    /// Logical payload volume: bytes delivered, counted per destination.
    pub fn payload_bytes(&self) -> u64 {
        self.payload_f32s.load(Ordering::Relaxed) * 4
    }
    /// Physical copy count: payload buffers the transport materialized.
    pub fn payload_clones(&self) -> u64 {
        self.payload_clones.load(Ordering::Relaxed)
    }
    /// Physical copy volume in bytes (0 for refcount-bump sends).
    pub fn bytes_copied(&self) -> u64 {
        self.bytes_copied.load(Ordering::Relaxed)
    }
    /// Messages lost to a disconnected destination endpoint.
    pub fn dead_letters(&self) -> u64 {
        self.dead_letters.load(Ordering::Relaxed)
    }
}

/// A communicator over `n` ranks, generic over the delivery backend (see
/// [`crate::comm::transport`]).
pub struct World {
    transport: Box<dyn TransportWorld>,
    latency: Duration,
    stats: Arc<WorldStats>,
    /// Installed fault plan (chaos runs only) and its anchor instant for
    /// time-triggered kills. `None` for the empty plan, so clean runs pay
    /// no per-endpoint fault state at all.
    fault: Option<(FaultPlan, Instant)>,
}

impl World {
    /// Create a world with `n` ranks and zero injected latency.
    pub fn new(n: usize) -> Self {
        Self::with_latency(n, Duration::ZERO)
    }

    /// Create a world where every message arrives `latency` after sending.
    pub fn with_latency(n: usize, latency: Duration) -> Self {
        Self::with_backend(n, latency, TransportKind::Channel)
    }

    /// Create a world over an explicit in-process transport backend.
    /// `TransportKind::Tcp` cannot be built here — a socket world needs the
    /// listen/connect bootstrap ([`World::listen`] / [`World::connect`]).
    pub fn with_backend(n: usize, latency: Duration, kind: TransportKind) -> Self {
        let transport: Box<dyn TransportWorld> = match kind {
            TransportKind::Channel => Box::new(ChannelWorld::new(n)),
            TransportKind::Shm => Box::new(ShmWorld::new(n)),
            TransportKind::Tcp => panic!(
                "tcp transport needs the socket bootstrap: use World::listen / World::connect"
            ),
        };
        Self::from_parts(transport, latency, Arc::new(WorldStats::default()))
    }

    /// Assemble a world around an already-constructed backend. The tcp
    /// bootstrap builds its backend first (it needs the stats handle to
    /// charge serialization copies) and then wraps it here.
    pub(crate) fn from_parts(
        transport: Box<dyn TransportWorld>,
        latency: Duration,
        stats: Arc<WorldStats>,
    ) -> Self {
        World { transport, latency, stats, fault: None }
    }

    pub fn size(&self) -> usize {
        self.transport.size()
    }

    /// Whether `rank` is homed in this process (always true for in-process
    /// backends; a tcp world homes only the ranks it was bootstrapped with).
    pub fn owns(&self, rank: usize) -> bool {
        self.transport.owns(rank)
    }

    pub fn stats(&self) -> Arc<WorldStats> {
        Arc::clone(&self.stats)
    }

    /// Install a fault plan. Must be called before endpoints are taken;
    /// time-triggered kills are anchored at the call instant. An empty plan
    /// is a no-op, keeping clean runs bit-identical.
    pub fn set_fault_plan(&mut self, plan: FaultPlan) {
        if !plan.is_empty() {
            self.fault = Some((plan, Instant::now()));
        }
    }

    /// Take rank `rank`'s endpoint. Each endpoint can be taken exactly once
    /// and moved into that kernel's host thread.
    pub fn endpoint(&mut self, rank: usize) -> Endpoint {
        Endpoint {
            rank,
            world_n: self.transport.size(),
            transport: self.transport.take(rank),
            pending: HashMap::new(),
            next_seq: 0,
            latency: self.latency,
            stats: Arc::clone(&self.stats),
            fault: self.fault.as_ref().and_then(|(p, t0)| p.compile(rank, *t0)),
            fault_active: self.fault.is_some(),
        }
    }

    /// Take all endpoints in rank order (convenience for spawning).
    pub fn endpoints(&mut self) -> Vec<Endpoint> {
        (0..self.size()).map(|r| self.endpoint(r)).collect()
    }

    /// A send-only handle for `rank`, usable alongside (and after) the
    /// rank's own endpoint. The workflow supervisor holds one per host so
    /// a panicking host's rank-down notification can be sent after the
    /// host body — and the endpoint it consumed — are gone.
    pub fn control_handle(&self, rank: usize) -> ControlHandle {
        ControlHandle {
            rank,
            tx: self.transport.control_sender(rank),
            latency: self.latency,
            stats: Arc::clone(&self.stats),
        }
    }
}

/// Send-only sibling of [`Endpoint`] (see [`World::control_handle`]).
/// Carries no mailbox, no fault state: control traffic about a fault must
/// not itself be subject to the dead rank's fault rules.
pub struct ControlHandle {
    rank: usize,
    tx: Box<dyn TransportSender>,
    latency: Duration,
    stats: Arc<WorldStats>,
}

impl ControlHandle {
    /// Send `data` to `dst`; `false` if the destination is disconnected
    /// (counted as a dead letter, like [`Endpoint::send`]).
    pub fn send(&self, dst: usize, tag: u32, data: Vec<f32>) -> bool {
        self.stats.messages.fetch_add(1, Ordering::Relaxed);
        self.stats.payload_f32s.fetch_add(data.len() as u64, Ordering::Relaxed);
        if !data.is_empty() {
            self.stats.payload_clones.fetch_add(1, Ordering::Relaxed);
            self.stats.bytes_copied.fetch_add(data.len() as u64 * 4, Ordering::Relaxed);
        }
        let ok = self.tx.send(
            dst,
            Message {
                src: self.rank,
                tag,
                data: Payload::from(data),
                ready_at: Instant::now() + self.latency,
                seq: 0,
            },
        );
        if !ok {
            self.stats.dead_letters.fetch_add(1, Ordering::Relaxed);
        }
        ok
    }
}

/// One rank's communication handle.
pub struct Endpoint {
    rank: usize,
    world_n: usize,
    /// The delivery backend for this rank (see [`crate::comm::transport`]).
    /// Self-sends are dropped inside the backend; disconnection (all peers
    /// + World gone) surfaces from its `recv_deadline`.
    transport: Box<dyn Transport>,
    /// Received-but-unmatched messages, indexed by tag (MPI-style
    /// out-of-order matching without rescanning unrelated traffic).
    pending: HashMap<u32, VecDeque<Message>>,
    /// Mailbox arrival stamp source (see [`Message::seq`]).
    next_seq: u64,
    latency: Duration,
    stats: Arc<WorldStats>,
    /// Compiled fault actions targeting this rank (`None` outside chaos
    /// runs and for untargeted ranks — one branch, no allocations).
    fault: Option<Box<FaultState>>,
    /// Whether the world has *any* fault plan installed. Lets callers keep
    /// recovery bookkeeping (e.g. retaining in-flight inputs for requeue)
    /// off the hot path unless a chaos run or adaptive policy needs it.
    fault_active: bool,
}

/// Matcher for receives: exact source or any.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Src {
    Any,
    Rank(usize),
}

impl Src {
    fn matches(&self, src: usize) -> bool {
        match self {
            Src::Any => true,
            Src::Rank(r) => *r == src,
        }
    }
}

impl Endpoint {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world_size(&self) -> usize {
        self.world_n
    }

    /// True when the world has a (non-empty) fault plan installed — chaos
    /// runs opt callers into failure-recovery bookkeeping that clean runs
    /// skip.
    pub fn fault_active(&self) -> bool {
        self.fault_active
    }

    fn note_copy(&self, copied: bool, len: usize) {
        if copied {
            self.stats.payload_clones.fetch_add(1, Ordering::Relaxed);
            self.stats.bytes_copied.fetch_add(len as u64 * 4, Ordering::Relaxed);
        }
    }

    /// Charge a physical payload materialization that happened *outside* a
    /// send — e.g. converting a staged row block into the shared payload
    /// whose row slices are then scattered copy-free. Keeps
    /// `bytes_copied`/`payload_clones` honest when the ingest copy and the
    /// sends are decoupled. Zero-length ingests resolve to the cached empty
    /// payload and cost nothing.
    pub fn note_ingest(&self, f32s: usize) {
        self.note_copy(f32s > 0, f32s);
    }

    /// Ship an already-shared payload to `dst`: refcount bump, no copy.
    /// `false` if the destination endpoint is gone (dead letter).
    fn send_payload(&self, dst: usize, tag: u32, data: Payload) -> bool {
        if let Some(f) = &self.fault {
            f.check_time(Instant::now());
        }
        self.stats.messages.fetch_add(1, Ordering::Relaxed);
        self.stats.payload_f32s.fetch_add(data.len() as u64, Ordering::Relaxed);
        // A send to a dropped destination endpoint is a *dead letter*: the
        // message is lost. During the shutdown drain that's benign by
        // design (drain discipline), but mid-run it means the peer's host
        // died — so it is counted and surfaced to the caller. Sends to
        // self are not part of the protocol and are dropped silently
        // (inside the backend, which reports them as delivered).
        let delivered = self.transport.send(
            dst,
            Message { src: self.rank, tag, data, ready_at: Instant::now() + self.latency, seq: 0 },
        );
        if !delivered {
            self.stats.dead_letters.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(f) = &self.fault {
            f.on_send(); // may panic: kill-after-Nth-send fires post-delivery
        }
        delivered
    }

    /// Point-to-point send. Never blocks (channels are unbounded); the
    /// injected latency delays *visibility*, not the sender. Accepts
    /// anything [`IntoPayload`]: pass a [`Payload`] (or `&Payload`) for a
    /// zero-copy send, or owned/borrowed data for a one-copy ingest.
    /// Returns `false` if the destination's endpoint is disconnected (its
    /// host died or shut down) — the message was not delivered and the
    /// loss is counted in [`WorldStats::dead_letters`].
    pub fn send<P: IntoPayload>(&self, dst: usize, tag: u32, data: P) -> bool {
        let (payload, copied) = data.into_payload();
        self.note_copy(copied, payload.len());
        self.send_payload(dst, tag, payload)
    }

    /// Broadcast the same payload to every rank in `dsts`. The payload is
    /// converted to shared storage at most once; each destination then gets
    /// a refcount bump, so physical copy cost is independent of `dsts.len()`.
    /// Returns how many destinations accepted the message; a shortfall
    /// means dead peers (each counted in [`WorldStats::dead_letters`]).
    pub fn bcast<P: IntoPayload>(&self, dsts: &[usize], tag: u32, data: P) -> usize {
        let (payload, copied) = data.into_payload();
        self.note_copy(copied, payload.len());
        let mut delivered = 0;
        for &d in dsts {
            if self.send_payload(d, tag, payload.clone()) {
                delivered += 1;
            }
        }
        delivered
    }

    /// Scatter one payload per destination (lengths may differ).
    pub fn scatter<P: IntoPayload>(&self, dsts: &[usize], tag: u32, payloads: Vec<P>) {
        assert_eq!(dsts.len(), payloads.len(), "scatter arity mismatch");
        for (&d, p) in dsts.iter().zip(payloads) {
            self.send(d, tag, p);
        }
    }

    /// Stamp and file an arrived message into its tag's mailbox.
    fn enqueue(&mut self, mut m: Message) {
        m.seq = self.next_seq;
        self.next_seq += 1;
        self.pending.entry(m.tag).or_default().push_back(m);
    }

    /// The single arrival choke point (both the non-blocking drain and the
    /// blocking park loop route through here): applies this rank's fault
    /// rules — kill-on-Nth-arrival, drop, extra delay — then files the
    /// message.
    fn arrive(&mut self, mut m: Message) {
        if let Some(f) = &self.fault {
            match f.on_arrival(m.src, m.tag) {
                ArrivalAction::Deliver => {}
                ArrivalAction::Drop => return,
                ArrivalAction::Delay(extra) => m.ready_at += extra,
            }
        }
        self.enqueue(m);
    }

    fn drain_transport(&mut self) {
        if let Some(f) = &self.fault {
            // idle hosts poll receives, so a time-triggered kill fires here
            // even if the rank never sends
            f.check_time(Instant::now());
        }
        while let Some(m) = self.transport.try_recv() {
            self.arrive(m);
        }
    }

    fn pop_pending(&mut self, src: Src, tag: u32) -> Option<Message> {
        self.pop_pending_tags(src, &[tag])
    }

    /// Pop the earliest-arrived ready message matching `src` and any of
    /// `tags`. Only the named tags' mailboxes are inspected; the earliest
    /// candidate across them (by arrival stamp) wins, preserving the
    /// first-available semantics of the old single-queue matcher.
    fn pop_pending_tags(&mut self, src: Src, tags: &[u32]) -> Option<Message> {
        let now = Instant::now();
        let mut best: Option<(u64, u32, usize)> = None;
        for &t in tags {
            if let Some(q) = self.pending.get(&t) {
                if let Some((idx, m)) = q
                    .iter()
                    .enumerate()
                    .find(|(_, m)| src.matches(m.src) && m.ready_at <= now)
                {
                    let earlier = match best {
                        None => true,
                        Some((s, _, _)) => m.seq < s,
                    };
                    if earlier {
                        best = Some((m.seq, t, idx));
                    }
                }
            }
        }
        let (_, tag, idx) = best?;
        let q = self.pending.get_mut(&tag).expect("candidate mailbox exists");
        let m = q.remove(idx);
        if q.is_empty() {
            self.pending.remove(&tag);
        }
        m
    }

    /// Whether any message matching `src` over `tags` exists in the
    /// mailboxes (ready or not; used for arrival-time waits).
    fn pending_matches(&self, src: Src, tags: &[u32]) -> bool {
        tags.iter()
            .filter_map(|t| self.pending.get(t))
            .flat_map(|q| q.iter())
            .any(|m| src.matches(m.src))
    }

    /// Non-blocking check whether a matching message is available
    /// (the paper's `req_data.Test()`).
    pub fn probe(&mut self, src: Src, tag: u32) -> bool {
        self.drain_transport();
        let now = Instant::now();
        match self.pending.get(&tag) {
            Some(q) => q.iter().any(|m| src.matches(m.src) && m.ready_at <= now),
            None => false,
        }
    }

    /// Blocking receive with timeout and MPI-style (src, tag) matching.
    ///
    /// Hot-path note (§Perf): before parking on the OS channel we spin a few
    /// times with `yield_now`. On a single-core host a blocked `recv` costs
    /// a full scheduler round-trip (~0.4 ms/hop measured); yielding lets the
    /// producer run immediately and cuts the exchange round-trip ~5x.
    pub fn recv_timeout(
        &mut self,
        src: Src,
        tag: u32,
        timeout: Duration,
    ) -> Result<Message, RecvError> {
        self.recv_timeout_tags(src, &[tag], timeout)
    }

    /// Blocking receive matching *any* of `tags` (first available wins;
    /// `Message::tag` tells the caller which). Used by hosts that serve
    /// multiple request kinds on one loop — e.g. predictors serving both
    /// lockstep broadcasts and batch frames.
    pub fn recv_timeout_tags(
        &mut self,
        src: Src,
        tags: &[u32],
        timeout: Duration,
    ) -> Result<Message, RecvError> {
        // short cooperative spin before blocking (shared anti-spin tuning:
        // transport::spin_then)
        if let Some(m) = transport::spin_then(|| {
            self.drain_transport();
            self.pop_pending_tags(src, tags)
        }) {
            return Ok(m);
        }
        let deadline = Instant::now() + timeout;
        loop {
            self.drain_transport();
            if let Some(m) = self.pop_pending_tags(src, tags) {
                return Ok(m);
            }
            // If a matching message exists but its simulated arrival is in
            // the future, sleep until it is ready (bounded by the deadline).
            let next_ready = tags
                .iter()
                .filter_map(|t| self.pending.get(t))
                .flat_map(|q| q.iter())
                .filter(|m| src.matches(m.src))
                .map(|m| m.ready_at)
                .min();
            let now = Instant::now();
            if now >= deadline {
                return Err(RecvError::Timeout);
            }
            let wait_until = next_ready.unwrap_or(deadline).min(deadline);
            if wait_until > now {
                match self.transport.recv_deadline(wait_until) {
                    Ok(m) => self.arrive(m),
                    Err(RecvError::Timeout) => {}
                    Err(RecvError::Disconnected) => {
                        // Drain pending before giving up.
                        if self.pending_matches(src, tags) {
                            continue;
                        }
                        return Err(RecvError::Disconnected);
                    }
                }
            }
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&mut self, src: Src, tag: u32) -> Option<Message> {
        self.drain_transport();
        self.pop_pending(src, tag)
    }

    /// Vectored receive: drain the channel once, then pop *every* ready
    /// message matching `(src, tag)` in arrival order. Gather-style
    /// consumers call this once per round instead of waking per message —
    /// one channel drain and one mailbox scan serve the whole batch.
    /// Messages whose simulated arrival time lies in the future stay
    /// queued, preserving the injected-latency semantics.
    pub fn recv_ready_all(&mut self, src: Src, tag: u32) -> Vec<Message> {
        self.drain_transport();
        let now = Instant::now();
        let Some(q) = self.pending.get_mut(&tag) else {
            return Vec::new();
        };
        let mut out = Vec::new();
        let mut i = 0;
        while i < q.len() {
            if src.matches(q[i].src) && q[i].ready_at <= now {
                out.push(q.remove(i).expect("index in bounds"));
            } else {
                i += 1;
            }
        }
        if q.is_empty() {
            self.pending.remove(&tag);
        }
        out
    }

    /// Put messages back at the front of their tag's mailbox, preserving
    /// their relative order (`msgs[0]` ends up frontmost). Used by gather
    /// loops to park a source's early next-round traffic: anything still
    /// queued behind it arrived later, so per-(src, tag) FIFO holds.
    pub fn requeue_front(&mut self, tag: u32, msgs: Vec<Message>) {
        if msgs.is_empty() {
            return;
        }
        let q = self.pending.entry(tag).or_default();
        for m in msgs.into_iter().rev() {
            q.push_front(m);
        }
    }

    /// Receive the *latest* matching message, discarding older ones
    /// (used for weight updates where only the newest matters).
    pub fn recv_latest(&mut self, src: Src, tag: u32) -> Option<Message> {
        let mut last = None;
        while let Some(m) = self.try_recv(src, tag) {
            last = Some(m);
        }
        last
    }

    /// Gather one message from every rank in `srcs` (any arrival order),
    /// returning payloads ordered like `srcs`.
    ///
    /// The receive is *vectored*: each pass drains the channel once
    /// ([`Endpoint::recv_ready_all`]) and files every ready message, so a
    /// round in which all sources have already replied costs one mailbox
    /// scan instead of one wake-up per source; only when nothing is ready
    /// does the loop park on the blocking receive.
    ///
    /// A second message from an already-filled source (the next round's
    /// traffic arriving early) is parked in a local deferred list and
    /// reinjected at the *front* of the tag's mailbox once the gather
    /// completes — per-(src, tag) FIFO is preserved because anything still
    /// queued arrived later. The match loop therefore never re-pops its own
    /// requeue, and needs no anti-spin sleep on the hot relay path.
    pub fn gather(
        &mut self,
        srcs: &[usize],
        tag: u32,
        timeout: Duration,
    ) -> Result<Vec<Payload>, RecvError> {
        let deadline = Instant::now() + timeout;
        let mut slots: Vec<Option<Message>> = vec![None; srcs.len()];
        let mut remaining = srcs.len();
        let mut deferred: Vec<Message> = Vec::new();
        let result = loop {
            if remaining == 0 {
                break Ok(());
            }
            let now = Instant::now();
            if now >= deadline {
                break Err(RecvError::Timeout);
            }
            let mut batch = self.recv_ready_all(Src::Any, tag);
            if batch.is_empty() {
                match self.recv_timeout(Src::Any, tag, deadline - now) {
                    Ok(m) => batch.push(m),
                    Err(e) => break Err(e),
                }
            }
            remaining -= fill_gather_slots(batch, srcs, &mut slots, &mut deferred);
        };
        // Oldest deferred message ends up frontmost: they were popped
        // earliest-first, so reinserting in reverse restores seq order.
        // (On a timeout the filled slots are intentionally *dropped*, not
        // requeued: they are replies to this gather's request and would be
        // stale for the next one.)
        self.requeue_front(tag, deferred);
        result?;
        Ok(slots.into_iter().map(|s| s.expect("all slots filled").data).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn point_to_point_roundtrip() {
        let mut w = World::new(2);
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        a.send(1, 7, vec![1.0, 2.0]);
        let m = b.recv_timeout(Src::Rank(0), 7, Duration::from_secs(1)).unwrap();
        assert_eq!(m.src, 0);
        assert_eq!(m.data, vec![1.0, 2.0]);
    }

    #[test]
    fn tag_matching_out_of_order() {
        let mut w = World::new(2);
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        a.send(1, 1, vec![1.0]);
        a.send(1, 2, vec![2.0]);
        // receive tag 2 first even though tag 1 arrived first
        let m2 = b.recv_timeout(Src::Rank(0), 2, Duration::from_secs(1)).unwrap();
        assert_eq!(m2.data, vec![2.0]);
        let m1 = b.recv_timeout(Src::Rank(0), 1, Duration::from_secs(1)).unwrap();
        assert_eq!(m1.data, vec![1.0]);
    }

    #[test]
    fn fifo_per_src_tag() {
        let mut w = World::new(2);
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        for i in 0..10 {
            a.send(1, 3, vec![i as f32]);
        }
        for i in 0..10 {
            let m = b.recv_timeout(Src::Rank(0), 3, Duration::from_secs(1)).unwrap();
            assert_eq!(m.data[0], i as f32);
        }
    }

    #[test]
    fn probe_is_nonblocking_test() {
        let mut w = World::new(2);
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        assert!(!b.probe(Src::Rank(0), 5));
        a.send(1, 5, vec![]);
        // drain into pending
        while !b.probe(Src::Rank(0), 5) {
            thread::yield_now();
        }
        assert!(b.try_recv(Src::Rank(0), 5).is_some());
        assert!(!b.probe(Src::Rank(0), 5));
    }

    #[test]
    fn multi_tag_recv_takes_first_available() {
        let mut w = World::new(2);
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        a.send(1, 5, vec![5.0]);
        a.send(1, 3, vec![3.0]);
        // arrival order wins across the tag set
        let m = b.recv_timeout_tags(Src::Rank(0), &[3, 5], Duration::from_secs(1)).unwrap();
        assert_eq!(m.tag, 5);
        let m = b.recv_timeout_tags(Src::Rank(0), &[3, 5], Duration::from_secs(1)).unwrap();
        assert_eq!(m.tag, 3);
        // non-listed tags don't match
        a.send(1, 9, vec![]);
        let r = b.recv_timeout_tags(Src::Rank(0), &[3, 5], Duration::from_millis(20));
        assert_eq!(r.unwrap_err(), RecvError::Timeout);
    }

    #[test]
    fn timeout_fires() {
        let mut w = World::new(2);
        let _a = w.endpoint(0);
        let mut b = w.endpoint(1);
        let r = b.recv_timeout(Src::Rank(0), 1, Duration::from_millis(20));
        assert_eq!(r.unwrap_err(), RecvError::Timeout);
    }

    #[test]
    fn disconnected_when_all_senders_drop() {
        let mut w = World::new(2);
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        drop(a);
        drop(w); // drops the stored sender clones too
        let r = b.recv_timeout(Src::Any, 1, Duration::from_secs(1));
        assert_eq!(r.unwrap_err(), RecvError::Disconnected);
    }

    #[test]
    fn gather_orders_by_src_list() {
        let mut w = World::new(4);
        let mut eps = w.endpoints();
        let e3 = eps.pop().unwrap();
        let e2 = eps.pop().unwrap();
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        // send in reverse rank order
        e3.send(0, 9, vec![3.0]);
        e2.send(0, 9, vec![2.0]);
        e1.send(0, 9, vec![1.0]);
        let got = e0.gather(&[1, 2, 3], 9, Duration::from_secs(1)).unwrap();
        assert_eq!(got, vec![vec![1.0], vec![2.0], vec![3.0]]);
    }

    #[test]
    fn gather_keeps_early_next_round_messages() {
        let mut w = World::new(2);
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        a.send(1, 9, vec![1.0]); // round 1
        a.send(1, 9, vec![2.0]); // round 2 arrives early
        let r1 = b.gather(&[0], 9, Duration::from_secs(1)).unwrap();
        assert_eq!(r1, vec![vec![1.0]]);
        let r2 = b.gather(&[0], 9, Duration::from_secs(1)).unwrap();
        assert_eq!(r2, vec![vec![2.0]]);
    }

    #[test]
    fn gather_defers_duplicates_without_reordering() {
        let mut w = World::new(3);
        let mut eps = w.endpoints();
        let e2 = eps.pop().unwrap();
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        // rank 1 races two rounds ahead before rank 2 sends round 1
        e1.send(0, 9, vec![1.0]); // round 1
        e1.send(0, 9, vec![10.0]); // round 2, early
        e1.send(0, 9, vec![100.0]); // round 3, early
        e2.send(0, 9, vec![2.0]); // round 1
        let r1 = e0.gather(&[1, 2], 9, Duration::from_secs(1)).unwrap();
        assert_eq!(r1, vec![vec![1.0], vec![2.0]]);
        // deferred messages replay in FIFO order on later gathers
        e2.send(0, 9, vec![20.0]);
        let r2 = e0.gather(&[1, 2], 9, Duration::from_secs(1)).unwrap();
        assert_eq!(r2, vec![vec![10.0], vec![20.0]]);
        e2.send(0, 9, vec![200.0]);
        let r3 = e0.gather(&[1, 2], 9, Duration::from_secs(1)).unwrap();
        assert_eq!(r3, vec![vec![100.0], vec![200.0]]);
    }

    #[test]
    fn recv_ready_all_drains_in_arrival_order() {
        let mut w = World::new(3);
        let mut eps = w.endpoints();
        let e2 = eps.pop().unwrap();
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.send(0, 9, vec![1.0]);
        e2.send(0, 9, vec![2.0]);
        e1.send(0, 9, vec![3.0]);
        e1.send(0, 8, vec![8.0]); // different tag: untouched
        // let the channel deliver
        thread::sleep(Duration::from_millis(5));
        let batch = e0.recv_ready_all(Src::Any, 9);
        let got: Vec<Vec<f32>> = batch.iter().map(|m| m.data.as_slice().to_vec()).collect();
        assert_eq!(got, vec![vec![1.0], vec![2.0], vec![3.0]]);
        // one drain takes everything ready; a second returns nothing
        assert!(e0.recv_ready_all(Src::Any, 9).is_empty());
        // the other tag's mailbox was not disturbed
        assert_eq!(e0.try_recv(Src::Rank(1), 8).unwrap().data, vec![8.0]);
    }

    #[test]
    fn recv_ready_all_filters_by_src() {
        let mut w = World::new(3);
        let mut eps = w.endpoints();
        let e2 = eps.pop().unwrap();
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.send(0, 7, vec![1.0]);
        e2.send(0, 7, vec![2.0]);
        thread::sleep(Duration::from_millis(5));
        let batch = e0.recv_ready_all(Src::Rank(2), 7);
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].data, vec![2.0]);
        // rank 1's message is still queued
        assert_eq!(e0.try_recv(Src::Rank(1), 7).unwrap().data, vec![1.0]);
    }

    #[test]
    fn requeue_front_restores_fifo() {
        let mut w = World::new(2);
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        for i in 0..4 {
            a.send(1, 5, vec![i as f32]);
        }
        thread::sleep(Duration::from_millis(5));
        let mut batch = b.recv_ready_all(Src::Any, 5);
        assert_eq!(batch.len(), 4);
        // keep the last two popped, put the first two back
        let keep: Vec<Message> = batch.drain(..2).collect();
        b.requeue_front(5, keep);
        for i in 0..2 {
            assert_eq!(b.try_recv(Src::Rank(0), 5).unwrap().data, vec![i as f32]);
        }
        assert!(b.try_recv(Src::Rank(0), 5).is_none());
        assert_eq!(batch[0].data, vec![2.0]);
    }

    #[test]
    fn requeued_messages_stay_ahead_of_later_arrivals() {
        // the oracle-plane drain discipline: frames drained but not yet
        // processed go back to the mailbox front, so traffic that arrived
        // *after* the drain can never be interleaved ahead of them
        let mut w = World::new(2);
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        a.send(1, 23, vec![1.0]);
        a.send(1, 23, vec![2.0]);
        thread::sleep(Duration::from_millis(5));
        let drained = b.recv_ready_all(Src::Any, 23);
        assert_eq!(drained.len(), 2);
        // a newer frame lands in the channel while the drain is parked
        a.send(1, 23, vec![3.0]);
        thread::sleep(Duration::from_millis(5));
        b.requeue_front(23, drained);
        for want in [1.0, 2.0, 3.0] {
            assert_eq!(b.try_recv(Src::Rank(0), 23).unwrap().data, vec![want]);
        }
    }

    #[test]
    fn scatter_delivers_distinct_payloads() {
        let mut w = World::new(3);
        let mut eps = w.endpoints();
        let mut e2 = eps.pop().unwrap();
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        e0.scatter(&[1, 2], 4, vec![vec![1.0], vec![2.0]]);
        assert_eq!(e1.recv_timeout(Src::Rank(0), 4, Duration::from_secs(1)).unwrap().data, vec![1.0]);
        assert_eq!(e2.recv_timeout(Src::Rank(0), 4, Duration::from_secs(1)).unwrap().data, vec![2.0]);
    }

    #[test]
    fn bcast_same_payload() {
        let mut w = World::new(3);
        let mut eps = w.endpoints();
        let mut e2 = eps.pop().unwrap();
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        e0.bcast(&[1, 2], 6, &[5.0, 6.0]);
        for e in [&mut e1, &mut e2] {
            assert_eq!(e.recv_timeout(Src::Rank(0), 6, Duration::from_secs(1)).unwrap().data, vec![5.0, 6.0]);
        }
    }

    #[test]
    fn latency_delays_visibility_not_sender() {
        let mut w = World::with_latency(2, Duration::from_millis(40));
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        let t0 = Instant::now();
        a.send(1, 1, vec![1.0]);
        let send_cost = t0.elapsed();
        assert!(send_cost < Duration::from_millis(10), "sender blocked {send_cost:?}");
        let m = b.recv_timeout(Src::Rank(0), 1, Duration::from_secs(1)).unwrap();
        assert_eq!(m.data, vec![1.0]);
        assert!(t0.elapsed() >= Duration::from_millis(35), "latency not applied");
    }

    #[test]
    fn recv_latest_discards_stale() {
        let mut w = World::new(2);
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        a.send(1, 8, vec![1.0]);
        a.send(1, 8, vec![2.0]);
        a.send(1, 8, vec![3.0]);
        thread::sleep(Duration::from_millis(5));
        let m = b.recv_latest(Src::Rank(0), 8).unwrap();
        assert_eq!(m.data, vec![3.0]);
        assert!(b.try_recv(Src::Rank(0), 8).is_none());
    }

    #[test]
    fn stats_count_messages_and_bytes() {
        let mut w = World::new(2);
        let stats = w.stats();
        let a = w.endpoint(0);
        let mut _b = w.endpoint(1);
        a.send(1, 1, vec![0.0; 10]);
        a.send(1, 1, vec![0.0; 5]);
        assert_eq!(stats.messages(), 2);
        assert_eq!(stats.payload_bytes(), 60);
        // Vec sends ingest into shared storage: one physical copy each
        assert_eq!(stats.payload_clones(), 2);
        assert_eq!(stats.bytes_copied(), 60);
    }

    #[test]
    fn bcast_of_shared_payload_is_zero_copy() {
        const DSTS: usize = 8;
        const LEN: usize = 1024;
        let mut w = World::new(DSTS + 1);
        let stats = w.stats();
        let mut eps = w.endpoints();
        let root = eps.remove(0);
        let dsts: Vec<usize> = (1..=DSTS).collect();
        let weights = Payload::from(vec![0.5f32; LEN]);
        root.bcast(&dsts, 31, &weights);
        // logical traffic scales with destination count ...
        assert_eq!(stats.messages(), DSTS as u64);
        assert_eq!(stats.payload_bytes(), (DSTS * LEN * 4) as u64);
        // ... physical copies do not happen at all
        assert_eq!(stats.payload_clones(), 0);
        assert_eq!(stats.bytes_copied(), 0);
        for e in eps.iter_mut() {
            let m = e.recv_timeout(Src::Rank(0), 31, Duration::from_secs(1)).unwrap();
            assert_eq!(m.data.len(), LEN);
        }
        // the old per-destination-clone pattern pays one copy per rank
        for &d in &dsts {
            root.send(d, 31, vec![0.5f32; LEN]);
        }
        assert_eq!(stats.payload_clones(), DSTS as u64);
        assert_eq!(stats.bytes_copied(), (DSTS * LEN * 4) as u64);
    }

    #[test]
    fn bcast_bytes_copied_flat_in_destination_count() {
        const LEN: usize = 256;
        let mut copied = Vec::new();
        let mut logical = Vec::new();
        for n in [2usize, 8] {
            let mut w = World::new(n + 1);
            let stats = w.stats();
            let mut eps = w.endpoints();
            let root = eps.remove(0);
            let dsts: Vec<usize> = (1..=n).collect();
            // owned Vec: exactly one ingest copy regardless of fan-out
            root.bcast(&dsts, 6, vec![0.25f32; LEN]);
            copied.push(stats.bytes_copied());
            logical.push(stats.payload_bytes());
            assert_eq!(stats.payload_clones(), 1);
        }
        assert_eq!(copied[0], copied[1], "physical copies must not scale with fan-out");
        assert_eq!(copied[0], (LEN * 4) as u64);
        assert_eq!(logical[1], 4 * logical[0], "logical bytes scale 2 -> 8 ranks");
    }

    #[test]
    fn payload_relay_resend_is_zero_copy() {
        let mut w = World::new(3);
        let stats = w.stats();
        let mut eps = w.endpoints();
        let mut e2 = eps.pop().unwrap();
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        e0.send(1, 1, vec![1.0, 2.0, 3.0]); // one ingest copy
        let m = e1.recv_timeout(Src::Rank(0), 1, Duration::from_secs(1)).unwrap();
        e1.send(2, 1, m.data); // relay hop: refcount bump only
        let m2 = e2.recv_timeout(Src::Rank(1), 1, Duration::from_secs(1)).unwrap();
        assert_eq!(m2.data, vec![1.0, 2.0, 3.0]);
        assert_eq!(stats.payload_clones(), 1);
        assert_eq!(stats.bytes_copied(), 12);
    }

    #[test]
    fn payload_slice_shares_backing_buffer() {
        let p = Payload::from(vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        let row = p.slice(2..4);
        assert_eq!(row.as_slice(), &[2.0, 3.0]);
        assert_eq!(row.shared_handles(), 2, "slice must share, not copy");
        // nested slices compose
        let sub = row.slice(1..2);
        assert_eq!(sub.as_slice(), &[3.0]);
        // empty range is fine
        assert_eq!(p.slice(6..6).len(), 0);
    }

    #[test]
    fn payload_ident_tracks_buffer_and_range() {
        let p = Payload::from(vec![0.0, 1.0, 2.0, 3.0]);
        // clones alias the same data → same identity
        assert_eq!(p.ident(), p.clone().ident());
        // a sub-view is a distinct identity on the same buffer
        assert_ne!(p.ident(), p.slice(0..2).ident());
        assert_eq!(p.slice(0..2).ident(), p.slice(0..2).ident());
        // equal values in a different buffer are a different identity
        let q = Payload::from(vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(p, q);
        assert_ne!(p.ident(), q.ident());
    }

    #[test]
    fn payload_row_scatter_is_zero_copy() {
        let mut w = World::new(3);
        let stats = w.stats();
        let mut eps = w.endpoints();
        let mut e2 = eps.pop().unwrap();
        let mut e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let block = Payload::from(vec![1.0, 2.0, 3.0, 4.0]); // one ingest
        e0.scatter(&[1, 2], 4, vec![block.slice(0..2), block.slice(2..4)]);
        assert_eq!(e1.recv_timeout(Src::Rank(0), 4, Duration::from_secs(1)).unwrap().data, vec![1.0, 2.0]);
        assert_eq!(e2.recv_timeout(Src::Rank(0), 4, Duration::from_secs(1)).unwrap().data, vec![3.0, 4.0]);
        // the scatter itself copied nothing
        assert_eq!(stats.payload_clones(), 0);
        assert_eq!(stats.bytes_copied(), 0);
    }

    #[test]
    fn empty_payload_is_cached_and_copy_free() {
        let a = Payload::empty();
        let b = Payload::empty();
        assert_eq!(a.len(), 0);
        // both handles share the OnceLock'd buffer (plus the cache's own)
        assert!(a.shared_handles() >= 2 && b.shared_handles() >= 2);
        // empty owned sends resolve to the cached payload: no clone counted
        let mut w = World::new(2);
        let stats = w.stats();
        let e0 = w.endpoint(0);
        let mut e1 = w.endpoint(1);
        e0.send(1, 90, vec![]);
        assert_eq!(stats.payload_clones(), 0);
        assert_eq!(stats.bytes_copied(), 0);
        assert_eq!(e1.recv_timeout(Src::Rank(0), 90, Duration::from_secs(1)).unwrap().data.len(), 0);
    }

    #[test]
    fn send_to_dropped_endpoint_is_a_counted_dead_letter() {
        let mut w = World::new(3);
        let stats = w.stats();
        let a = w.endpoint(0);
        let b = w.endpoint(1);
        let mut c = w.endpoint(2);
        drop(b); // rank 1's host dies
        assert!(!a.send(1, 7, vec![1.0]), "send to a dead rank must report failure");
        assert_eq!(stats.dead_letters(), 1);
        // live peers are unaffected
        assert!(a.send(2, 7, vec![2.0]));
        assert_eq!(c.recv_timeout(Src::Rank(0), 7, Duration::from_secs(1)).unwrap().data, vec![
            2.0
        ]);
        // bcast reports the delivered count and charges the shortfall
        assert_eq!(a.bcast(&[1, 2], 8, vec![3.0]), 1);
        assert_eq!(stats.dead_letters(), 2);
    }

    #[test]
    fn control_handle_sends_after_endpoint_drop() {
        let mut w = World::new(2);
        let stats = w.stats();
        let ctrl = w.control_handle(0);
        let ep0 = w.endpoint(0);
        let mut e1 = w.endpoint(1);
        drop(ep0); // the host body (and its endpoint) are gone
        assert!(ctrl.send(1, 92, vec![0.0]));
        let m = e1.recv_timeout(Src::Rank(0), 92, Duration::from_secs(1)).unwrap();
        assert_eq!(m.src, 0);
        // a control send to a dead rank is a dead letter like any other
        drop(e1);
        drop(w);
        assert!(!ctrl.send(1, 92, vec![0.0]));
        assert_eq!(stats.dead_letters(), 1);
    }

    #[test]
    fn fault_kill_after_sends_delivers_then_dies() {
        use crate::comm::fault::{FaultKill, FaultPlan};
        let mut w = World::new(2);
        w.set_fault_plan(FaultPlan::default().kill_after_sends(0, 2));
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            a.send(1, 1, vec![1.0]);
            a.send(1, 1, vec![2.0]); // dies here, after delivery
            a.send(1, 1, vec![3.0]);
        }));
        let err = r.unwrap_err();
        assert_eq!(err.downcast_ref::<FaultKill>(), Some(&FaultKill { rank: 0 }));
        // both pre-kill sends were delivered; nothing after
        for want in [1.0, 2.0] {
            let m = b.recv_timeout(Src::Rank(0), 1, Duration::from_secs(1)).unwrap();
            assert_eq!(m.data, vec![want]);
        }
        assert!(b.try_recv(Src::Rank(0), 1).is_none());
    }

    #[test]
    fn fault_drop_and_delay_rules_apply_on_arrival() {
        use crate::comm::fault::FaultPlan;
        let mut w = World::new(2);
        w.set_fault_plan(
            FaultPlan::default()
                .drop_msgs(1, 0, 7, 1)
                .delay_msgs(1, 0, 9, Duration::from_millis(40), 1),
        );
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        assert!(b.fault_active());
        // first tag-7 frame is dropped on arrival; the second delivers
        a.send(1, 7, vec![1.0]);
        a.send(1, 7, vec![2.0]);
        let m = b.recv_timeout(Src::Rank(0), 7, Duration::from_secs(1)).unwrap();
        assert_eq!(m.data, vec![2.0]);
        assert!(b.try_recv(Src::Rank(0), 7).is_none());
        // the delayed tag-9 frame arrives late but intact
        let t0 = Instant::now();
        a.send(1, 9, vec![9.0]);
        let m = b.recv_timeout(Src::Rank(0), 9, Duration::from_secs(1)).unwrap();
        assert_eq!(m.data, vec![9.0]);
        assert!(t0.elapsed() >= Duration::from_millis(35), "delay rule not applied");
    }

    #[test]
    fn empty_fault_plan_installs_nothing() {
        use crate::comm::fault::FaultPlan;
        let mut w = World::new(2);
        w.set_fault_plan(FaultPlan::default());
        let a = w.endpoint(0);
        let mut b = w.endpoint(1);
        assert!(!a.fault_active() && !b.fault_active());
        assert!(a.send(1, 1, vec![1.0]));
        assert_eq!(b.recv_timeout(Src::Rank(0), 1, Duration::from_secs(1)).unwrap().data, vec![
            1.0
        ]);
    }

    #[test]
    fn cross_thread_pingpong() {
        let mut w = World::new(2);
        let mut e0 = w.endpoint(0);
        let mut e1 = w.endpoint(1);
        let h = thread::spawn(move || {
            for _ in 0..100 {
                let m = e1.recv_timeout(Src::Rank(0), 1, Duration::from_secs(5)).unwrap();
                e1.send(0, 2, m.data);
            }
        });
        for i in 0..100 {
            e0.send(1, 1, vec![i as f32]);
            let m = e0.recv_timeout(Src::Rank(1), 2, Duration::from_secs(5)).unwrap();
            assert_eq!(m.data[0], i as f32);
        }
        h.join().unwrap();
    }
}
