//! Pluggable transport plane: the seam between [`crate::comm::bus`] and
//! the mechanism that physically moves [`Message`]s between ranks.
//!
//! The bus keeps everything protocol-level — per-tag mailboxes, MPI-style
//! `(src, tag)` matching, arrival-order stamps, injected latency,
//! [`crate::comm::fault`] rules, and the logical/physical byte accounting
//! in [`crate::comm::bus::WorldStats`]. A [`Transport`] only delivers:
//! `send(dst, Message) -> bool` (did the destination still exist?) plus a
//! non-blocking `try_recv` and a parking `recv_deadline`. Because every
//! backend slots in *under* the mailbox layer, the fault plane, latency
//! injection, zero-copy payload model, and dead-letter semantics carry
//! over to all backends unchanged — that shared contract is pinned by the
//! cross-backend conformance suite in `rust/tests/test_transport.rs`.
//!
//! Three backends:
//!
//! * [`channel`] — the original `std::sync::mpsc` bus, one unbounded
//!   channel per rank. The default; behavior is bit-identical to the
//!   pre-trait bus.
//! * [`shm`] — lock-free shared-memory-style backend: one fixed-capacity
//!   SPSC-style ring FIFO per (src, dst) rank pair (multi-producer-safe
//!   for the control plane), block ownership handed off on send. No
//!   mutex, no per-message channel-node allocation on the hot path;
//!   `Payload` fan-out stays refcount-only.
//! * [`tcp`] — length-prefixed framed sockets over `std::net` for true
//!   multi-process runs: per-peer writer threads, a demux reader feeding
//!   the per-rank inboxes, connect retry/backoff, and star-topology
//!   relay through the listener. Bootstrapped via
//!   [`crate::comm::World::listen`] / [`crate::comm::World::connect`].

use std::time::Instant;

use crate::comm::bus::{Message, RecvError};

pub mod channel;
pub mod shm;
pub mod tcp;

/// Which transport backend a [`crate::comm::World`] runs over.
///
/// Selected per run via the `transport` JSON key ("channel" | "shm" |
/// "tcp") or `pal run --transport=...`; `tcp` additionally needs the
/// listen/connect bootstrap (see [`tcp`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportKind {
    /// `std::sync::mpsc` channels (default, in-process).
    #[default]
    Channel,
    /// Lock-free per-rank-pair rings (in-process, shared-memory idiom).
    Shm,
    /// Framed sockets over `std::net` (multi-process).
    Tcp,
}

impl TransportKind {
    /// Parse a config/CLI spelling. Unknown values are a loud error that
    /// names the accepted spellings.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "channel" => Ok(TransportKind::Channel),
            "shm" => Ok(TransportKind::Shm),
            "tcp" => Ok(TransportKind::Tcp),
            other => Err(format!("unknown transport: {other} (channel|shm|tcp)")),
        }
    }

    /// The config/CLI spelling (inverse of [`TransportKind::parse`]).
    pub fn as_str(&self) -> &'static str {
        match self {
            TransportKind::Channel => "channel",
            TransportKind::Shm => "shm",
            TransportKind::Tcp => "tcp",
        }
    }
}

impl std::fmt::Display for TransportKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One rank's delivery mechanism, owned by that rank's
/// [`crate::comm::Endpoint`].
///
/// Contract shared by every backend (the conformance suite's subject):
///
/// * `send` never blocks on the receiver being slow for the channel and
///   tcp backends; the shm backend applies bounded backpressure when a
///   ring is full but never deadlocks against a dead peer.
/// * A send to the endpoint's *own* rank is dropped and reports `true` —
///   self-sends are not part of the protocol (mirrors the channel bus's
///   `None` self-slot).
/// * `send` returns `false` exactly when the destination endpoint no
///   longer exists; the caller (the endpoint) counts the dead letter.
/// * `recv_deadline` returns [`RecvError::Disconnected`] only once no
///   live producer could ever deliver again (all peers + world gone),
///   matching `mpsc` disconnection semantics.
///
/// Stats hooks: backends that physically copy payload bytes (tcp
/// serialization) charge [`crate::comm::bus::WorldStats`] directly via
/// the `Arc<WorldStats>` handed to their world at construction; the
/// in-process backends move `Arc`-backed payloads and charge nothing.
pub trait Transport: Send {
    /// Deliver `m` to rank `dst`. `false` = destination gone (the caller
    /// records the dead letter).
    fn send(&self, dst: usize, m: Message) -> bool;

    /// Non-blocking: next transport-delivered message, if any.
    fn try_recv(&mut self) -> Option<Message>;

    /// Park until a message arrives, `deadline` passes, or the world
    /// disconnects. Implementations use [`spin_then`] before any
    /// OS-level wait so the anti-spin tuning lives in one place.
    fn recv_deadline(&mut self, deadline: Instant) -> Result<Message, RecvError>;
}

/// Send-only sibling of [`Transport`], cloned off the world *before* the
/// rank's endpoint exists and usable after it is gone — the delivery arm
/// of [`crate::comm::ControlHandle`]. Routes on `Message::src`/`dst`
/// exactly like the owning rank's `Transport::send`.
pub trait TransportSender: Send {
    fn send(&self, dst: usize, m: Message) -> bool;
}

/// A backend's world half: constructs per-rank [`Transport`]s (each rank
/// taken exactly once) and send-only control handles.
pub trait TransportWorld: Send {
    fn size(&self) -> usize;

    /// Take rank `rank`'s transport. Panics if taken twice or (for
    /// multi-process backends) if the rank is not homed in this process.
    fn take(&mut self, rank: usize) -> Box<dyn Transport>;

    /// A send-only handle sourcing messages from `rank`.
    fn control_sender(&self, rank: usize) -> Box<dyn TransportSender>;

    /// Whether `rank` is homed in this process (always true for the
    /// in-process backends; the tcp backend homes only its local ranks).
    fn owns(&self, rank: usize) -> bool {
        let _ = rank;
        true
    }
}

/// Cooperative yields every receive performs before parking (§Perf note
/// on [`crate::comm::bus::Endpoint::recv_timeout`]): on a single-core
/// host a blocked receive costs a full scheduler round-trip (~0.4 ms/hop
/// measured); yielding lets the producer run immediately and cuts the
/// exchange round-trip ~5x. This constant — and [`spin_then`] below —
/// is the *single* home of that anti-spin tuning, shared by the
/// endpoint's mailbox wait and every backend's park loop.
pub const SPIN_YIELDS: usize = 8;

/// Spin-then-park front half: poll up to [`SPIN_YIELDS`] times with a
/// `yield_now` between attempts, returning the first hit. `None` means
/// the caller should fall through to its backend's real parking wait.
pub fn spin_then<T>(mut poll: impl FnMut() -> Option<T>) -> Option<T> {
    for _ in 0..SPIN_YIELDS {
        if let Some(v) = poll() {
            return Some(v);
        }
        std::thread::yield_now();
    }
    None
}
