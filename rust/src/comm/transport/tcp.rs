//! Length-prefixed framed socket backend over `std::net`: the transport
//! that takes a [`crate::comm::World`] across OS process boundaries.
//!
//! ## Topology
//!
//! One process is the *listener* (in the workflow: the process hosting the
//! Manager) and every other process *connects* to it — a star. Each process
//! homes a disjoint set of ranks; frames addressed to a rank the listener
//! does not home are relayed to the peer that advertised it, so two
//! follower processes can exchange traffic through the listener without a
//! full mesh. Bootstrap is [`Bootstrap::bind`] (split from the accept so
//! tests can bind port 0 and read the real port back) +
//! [`World::listen`] / [`World::connect`]; `connect` retries with doubling
//! backoff so process launch order does not matter.
//!
//! ## Wire format
//!
//! All integers are little-endian `u32`. The handshake each side sends on
//! connect is `[MAGIC, world_n, k, rank_0 .. rank_{k-1}]` — the ranks the
//! sender homes. After the handshake the stream is a sequence of frames:
//! `[src, dst, tag, len]` followed by `len` payload `f32`s (LE bytes).
//! `Message::ready_at` does not travel — the receiving process re-stamps
//! arrival time (+ injected latency) when the frame lands, since `Instant`s
//! are meaningless across processes.
//!
//! ## Threads and accounting
//!
//! Per peer socket: one *writer* thread (drains an `mpsc` queue of
//! outbound messages, serializes into a `BufWriter`, flushes when the
//! queue runs dry) and one *reader* thread (demuxes inbound frames to the
//! homed ranks' inboxes, or relays them on the listener). Serialization is
//! the one place this crate physically copies payload bytes per
//! destination, and it is charged to [`WorldStats::bytes_copied`] /
//! `payload_clones` by the writer; in-process traffic between two ranks
//! homed in the same process stays refcount-only, exactly like the channel
//! backend.
//!
//! ## Shutdown
//!
//! Cross-process endpoint death cannot be observed synchronously, so
//! `send` to a remote rank only fails once the carrying socket is gone.
//! Each bootstrap returns a [`LinkMonitor`]; a process that serves
//! request/reply hosts (the follower running oracle ranks) watches
//! [`LinkMonitor::all_peers_closed`] and raises its local down flag when
//! the far side hangs up — that is the cross-process analogue of the
//! in-process `Disconnected` drain.

use std::io::{self, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Weak};
use std::time::{Duration, Instant};

use crate::comm::bus::{Message, Payload, RecvError, World, WorldStats};
use crate::comm::transport::{Transport, TransportSender, TransportWorld};

/// Handshake magic: "PAL1".
const MAGIC: u32 = 0x50414C31;

/// First connect-retry delay; doubles per attempt up to [`BACKOFF_MAX`].
const BACKOFF_START: Duration = Duration::from_millis(10);
const BACKOFF_MAX: Duration = Duration::from_millis(200);

/// A bound-but-not-yet-accepting listener. Binding is split from
/// [`World::listen`] so the caller can bind `127.0.0.1:0`, read the real
/// port with [`Bootstrap::local_addr`], and hand it to the follower
/// processes before blocking in accept.
pub struct Bootstrap {
    listener: TcpListener,
}

impl Bootstrap {
    pub fn bind(addr: &str) -> io::Result<Bootstrap> {
        Ok(Bootstrap { listener: TcpListener::bind(addr)? })
    }

    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }
}

/// Watch over the process's peer links (see module docs, "Shutdown").
#[derive(Clone)]
pub struct LinkMonitor {
    peers_open: Arc<AtomicUsize>,
}

impl LinkMonitor {
    pub fn peers_open(&self) -> usize {
        self.peers_open.load(Ordering::Acquire)
    }

    /// True once every peer socket has closed — no remote rank can be
    /// reached or heard from again.
    pub fn all_peers_closed(&self) -> bool {
        self.peers_open() == 0
    }
}

/// One outbound frame, still unserialized (the payload is a refcounted
/// view until the writer thread hits the socket).
struct WireMsg {
    src: usize,
    dst: usize,
    tag: u32,
    data: Payload,
}

struct Peer {
    tx: Sender<WireMsg>,
}

struct TcpState {
    n: usize,
    latency: Duration,
    stats: Arc<WorldStats>,
    /// Ranks homed in this process.
    local: Vec<bool>,
    /// Inbox senders for homed ranks (the paired receiver lives in that
    /// rank's [`TcpTransport`]).
    inbox_tx: Vec<Option<Sender<Message>>>,
    peers: Vec<Peer>,
    /// rank → peer index carrying it (remote ranks only).
    route: Vec<Option<usize>>,
}

impl TcpState {
    /// Deliver locally or enqueue on the carrying peer's writer. Shared by
    /// endpoint transports and control senders.
    fn send(&self, dst: usize, m: Message) -> bool {
        if dst == m.src {
            return true; // self-send: dropped by design, not a dead peer
        }
        if self.local[dst] {
            return match &self.inbox_tx[dst] {
                Some(tx) => tx.send(m).is_ok(),
                None => false,
            };
        }
        let Some(p) = self.route[dst].map(|i| &self.peers[i]) else {
            return false;
        };
        p.tx.send(WireMsg { src: m.src, dst, tag: m.tag, data: m.data }).is_ok()
    }
}

// ---------------------------------------------------------------------------
// wire helpers

fn write_u32s<W: Write>(w: &mut W, vals: &[u32]) -> io::Result<()> {
    for &v in vals {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn read_u32<R: Read>(r: &mut R) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Send our handshake, read and validate the peer's; returns the ranks the
/// peer homes.
fn handshake(stream: &mut TcpStream, n: usize, local: &[usize]) -> io::Result<Vec<usize>> {
    let mut ours = vec![MAGIC, n as u32, local.len() as u32];
    ours.extend(local.iter().map(|&r| r as u32));
    write_u32s(stream, &ours)?;
    stream.flush()?;
    if read_u32(stream)? != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad transport handshake magic"));
    }
    if read_u32(stream)? as usize != n {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "world size mismatch in handshake"));
    }
    let k = read_u32(stream)? as usize;
    let mut ranks = Vec::with_capacity(k);
    for _ in 0..k {
        let r = read_u32(stream)? as usize;
        if r >= n {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "handshake rank out of range"));
        }
        ranks.push(r);
    }
    Ok(ranks)
}

/// Writer thread body: serialize queued frames, flush when the queue runs
/// dry, exit when the queue disconnects or the socket dies. Serialization
/// is charged as the physical copy it is.
fn writer_loop(stream: TcpStream, rx: Receiver<WireMsg>, stats: Arc<WorldStats>) {
    let mut w = BufWriter::new(stream);
    let mut scratch: Vec<u8> = Vec::new();
    'link: while let Ok(m) = rx.recv() {
        let mut next = Some(m);
        while let Some(m) = next {
            let data = m.data.as_slice();
            scratch.clear();
            scratch.reserve(16 + data.len() * 4);
            for v in [m.src as u32, m.dst as u32, m.tag, data.len() as u32] {
                scratch.extend_from_slice(&v.to_le_bytes());
            }
            for &f in data {
                scratch.extend_from_slice(&f.to_le_bytes());
            }
            if !data.is_empty() {
                stats.payload_clones.fetch_add(1, Ordering::Relaxed);
                stats.bytes_copied.fetch_add(data.len() as u64 * 4, Ordering::Relaxed);
            }
            if w.write_all(&scratch).is_err() {
                break 'link;
            }
            next = rx.try_recv().ok();
        }
        if w.flush().is_err() {
            break 'link;
        }
    }
    // The queue disconnected (this process's world is gone) or the socket
    // died. Send FIN so the remote reader sees EOF even while our own
    // reader thread still holds a clone of the socket open.
    let _ = w.flush();
    let _ = w.get_ref().shutdown(std::net::Shutdown::Write);
}

/// Reader thread body: demux inbound frames to homed ranks (stamping
/// arrival + injected latency) or relay them toward the peer that homes
/// the destination (listener only). Decrements the peer count on exit so
/// the [`LinkMonitor`] sees the hangup.
///
/// Holds the state only *weakly*: once every world/endpoint/control handle
/// in this process is gone the state must drop (that is what disconnects
/// the writer queues and closes the sockets), so a blocked reader must not
/// keep it alive.
fn reader_loop(mut stream: TcpStream, state: Weak<TcpState>, peers_open: Arc<AtomicUsize>) {
    loop {
        let mut hdr = [0u8; 16];
        if stream.read_exact(&mut hdr).is_err() {
            break;
        }
        let word = |i: usize| u32::from_le_bytes(hdr[i * 4..i * 4 + 4].try_into().unwrap());
        let (src, dst, tag, len) =
            (word(0) as usize, word(1) as usize, word(2), word(3) as usize);
        let mut bytes = vec![0u8; len * 4];
        if stream.read_exact(&mut bytes).is_err() {
            break;
        }
        let Some(state) = state.upgrade() else {
            break; // our side of the world is gone; nothing to deliver to
        };
        if src >= state.n || dst >= state.n {
            break; // corrupt frame: drop the link
        }
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        if state.local[dst] {
            let m = Message {
                src,
                tag,
                data: Payload::from(floats),
                ready_at: Instant::now() + state.latency,
                seq: 0,
            };
            let delivered = match &state.inbox_tx[dst] {
                Some(tx) => tx.send(m).is_ok(),
                None => false,
            };
            if !delivered {
                state.stats.dead_letters.fetch_add(1, Ordering::Relaxed);
            }
        } else if let Some(p) = state.route[dst].map(|i| &state.peers[i]) {
            // star relay: forward toward the process homing `dst`
            let _ = p.tx.send(WireMsg { src, dst, tag, data: Payload::from(floats) });
        }
    }
    peers_open.fetch_sub(1, Ordering::AcqRel);
}

// ---------------------------------------------------------------------------
// backend types

pub struct TcpWorld {
    state: Arc<TcpState>,
    inbox_rx: Vec<Option<Receiver<Message>>>,
}

impl TransportWorld for TcpWorld {
    fn size(&self) -> usize {
        self.state.n
    }

    fn take(&mut self, rank: usize) -> Box<dyn Transport> {
        assert!(self.state.local[rank], "rank {rank} is not homed in this process");
        let rx = self.inbox_rx[rank].take().expect("endpoint already taken");
        Box::new(TcpTransport { rx, state: Arc::clone(&self.state) })
    }

    fn control_sender(&self, _rank: usize) -> Box<dyn TransportSender> {
        Box::new(TcpSender { state: Arc::clone(&self.state) })
    }

    fn owns(&self, rank: usize) -> bool {
        self.state.local[rank]
    }
}

pub struct TcpTransport {
    rx: Receiver<Message>,
    state: Arc<TcpState>,
}

impl Transport for TcpTransport {
    fn send(&self, dst: usize, m: Message) -> bool {
        self.state.send(dst, m)
    }

    fn try_recv(&mut self) -> Option<Message> {
        self.rx.try_recv().ok()
    }

    fn recv_deadline(&mut self, deadline: Instant) -> Result<Message, RecvError> {
        match self.rx.recv_timeout(deadline.saturating_duration_since(Instant::now())) {
            Ok(m) => Ok(m),
            Err(RecvTimeoutError::Timeout) => Err(RecvError::Timeout),
            Err(RecvTimeoutError::Disconnected) => Err(RecvError::Disconnected),
        }
    }
}

pub struct TcpSender {
    state: Arc<TcpState>,
}

impl TransportSender for TcpSender {
    fn send(&self, dst: usize, m: Message) -> bool {
        self.state.send(dst, m)
    }
}

// ---------------------------------------------------------------------------
// bootstrap

fn build_state(
    n: usize,
    local: &[usize],
    latency: Duration,
    stats: &Arc<WorldStats>,
    peers: Vec<Peer>,
    route: Vec<Option<usize>>,
) -> (Arc<TcpState>, Vec<Option<Receiver<Message>>>) {
    let mut is_local = vec![false; n];
    for &r in local {
        is_local[r] = true;
    }
    let mut inbox_tx: Vec<Option<Sender<Message>>> = (0..n).map(|_| None).collect();
    let mut inbox_rx: Vec<Option<Receiver<Message>>> = (0..n).map(|_| None).collect();
    for &r in local {
        let (tx, rx) = channel();
        inbox_tx[r] = Some(tx);
        inbox_rx[r] = Some(rx);
    }
    let state = Arc::new(TcpState {
        n,
        latency,
        stats: Arc::clone(stats),
        local: is_local,
        inbox_tx,
        peers,
        route,
    });
    (state, inbox_rx)
}

impl World {
    /// Listener-side bootstrap of a tcp world over `n` ranks, homing
    /// `local` in this process. Blocks accepting connections until every
    /// non-local rank is advertised by some peer, then starts the per-peer
    /// reader/writer threads. Returns the world plus the process's
    /// [`LinkMonitor`].
    pub fn listen(
        bootstrap: Bootstrap,
        n: usize,
        local: &[usize],
        latency: Duration,
    ) -> io::Result<(World, LinkMonitor)> {
        let stats = Arc::new(WorldStats::default());
        let mut covered = vec![false; n];
        for &r in local {
            covered[r] = true;
        }
        let mut route: Vec<Option<usize>> = vec![None; n];
        let mut conns: Vec<TcpStream> = Vec::new();
        while covered.iter().any(|&c| !c) {
            let (mut stream, _) = bootstrap.listener.accept()?;
            stream.set_nodelay(true).ok();
            let ranks = handshake(&mut stream, n, local)?;
            let idx = conns.len();
            for r in ranks {
                if covered[r] {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("rank {r} advertised by two processes"),
                    ));
                }
                covered[r] = true;
                route[r] = Some(idx);
            }
            conns.push(stream);
        }
        finish(n, local, latency, stats, conns, route)
    }

    /// Connector-side bootstrap: dial the listener at `addr` (retrying
    /// with backoff until `timeout`), home `local` in this process, and
    /// route every other rank through the listener (star relay).
    pub fn connect(
        addr: &str,
        n: usize,
        local: &[usize],
        latency: Duration,
        timeout: Duration,
    ) -> io::Result<(World, LinkMonitor)> {
        let deadline = Instant::now() + timeout;
        let mut backoff = BACKOFF_START;
        let mut stream = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() + backoff > deadline {
                        return Err(e);
                    }
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(BACKOFF_MAX);
                }
            }
        };
        stream.set_nodelay(true).ok();
        handshake(&mut stream, n, local)?;
        let stats = Arc::new(WorldStats::default());
        let mut route: Vec<Option<usize>> = vec![None; n];
        let local_set: Vec<bool> = {
            let mut v = vec![false; n];
            for &r in local {
                v[r] = true;
            }
            v
        };
        for (r, slot) in route.iter_mut().enumerate() {
            if !local_set[r] {
                *slot = Some(0);
            }
        }
        finish(n, local, latency, stats, vec![stream], route)
    }
}

/// Shared tail of both bootstraps: wire up writer queues, build the state,
/// spawn the per-peer threads (readers last, so the relay table they use
/// is complete), assemble the [`World`].
fn finish(
    n: usize,
    local: &[usize],
    latency: Duration,
    stats: Arc<WorldStats>,
    conns: Vec<TcpStream>,
    route: Vec<Option<usize>>,
) -> io::Result<(World, LinkMonitor)> {
    let mut peers = Vec::with_capacity(conns.len());
    let mut writer_parts = Vec::with_capacity(conns.len());
    for stream in &conns {
        let (tx, rx) = channel::<WireMsg>();
        peers.push(Peer { tx });
        writer_parts.push((stream.try_clone()?, rx));
    }
    let (state, inbox_rx) = build_state(n, local, latency, &stats, peers, route);
    for (stream, rx) in writer_parts {
        let stats = Arc::clone(&stats);
        std::thread::spawn(move || writer_loop(stream, rx, stats));
    }
    let peers_open = Arc::new(AtomicUsize::new(conns.len()));
    for stream in conns {
        let state = Arc::downgrade(&state);
        let peers_open = Arc::clone(&peers_open);
        std::thread::spawn(move || reader_loop(stream, state, peers_open));
    }
    let world =
        World::from_parts(Box::new(TcpWorld { state, inbox_rx }), latency, stats);
    Ok((world, LinkMonitor { peers_open }))
}
