//! Lock-free shared-memory-style backend: one fixed-capacity ring FIFO
//! per (src, dst) rank pair, modeled on the shared-memory BTL idiom
//! (fixed block store + per-pair FIFO; block *ownership* is handed off on
//! send, so a [`Message`]'s `Arc`-backed payload moves by refcount, never
//! by copy). There is no mutex and no per-message channel-node
//! allocation anywhere on the hot path: a send is one CAS on the ring
//! tail plus a slot write, a receive is one atomic load per non-empty
//! peer ring plus a slot read.
//!
//! Each ring is consumed only by its destination rank (single consumer)
//! but written with a multi-producer-safe sequence protocol (Vyukov
//! bounded-queue style), because a rank's [`crate::comm::ControlHandle`]
//! may produce concurrently with — or after — the rank's own endpoint.
//!
//! ## Ordering
//!
//! Per (src, dst) FIFO is the ring's own order. *Cross-source* arrival
//! order — which the channel backend gets for free from its single
//! receiver queue — is reconstructed by popping the peer ring whose head
//! message has the earliest send stamp (`Message::ready_at`, monotonic
//! across threads), with lowest source rank breaking exact ties. The
//! conformance suite in `rust/tests/test_transport.rs` pins this against
//! the channel backend.
//!
//! ## Liveness and dead letters
//!
//! A per-rank state word (untaken → live → dropped) plus a world-open
//! flag reproduce the channel bus's semantics exactly: sends to a
//! dropped endpoint fail (dead letter), sends to a not-yet-taken rank of
//! a live world queue up, and a receiver reports
//! [`RecvError::Disconnected`] only when the world and every peer
//! endpoint are gone and its inbound rings are drained. A full ring
//! applies bounded backpressure (yield-and-retry) instead of allocating;
//! the retry loop rechecks destination liveness, so it can never spin
//! against a dead peer.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::comm::bus::{Message, RecvError};
use crate::comm::transport::{self, Transport, TransportSender, TransportWorld};

/// Slots per rank-pair ring. Power of two; deep enough that the bounded
/// backpressure path is cold for the workflow's bounded-outstanding
/// traffic, small enough that a full toy topology (33 ranks → 33² rings)
/// stays in the tens of megabytes.
const RING_CAP: usize = 128;

/// How long the park loop naps between polls once the spin phase
/// (`transport::spin_then`) has run dry. Bounded by the caller deadline.
const PARK_NAP: Duration = Duration::from_micros(200);

/// Rank lifecycle states (`ShmState::rank_state`).
const UNTAKEN: usize = 0;
const LIVE: usize = 1;
const DROPPED: usize = 2;

struct Slot {
    /// Vyukov sequence word: `pos` = empty and claimable at `pos`,
    /// `pos + 1` = full, `pos + cap` = empty for the next lap.
    seq: AtomicUsize,
    msg: UnsafeCell<Option<Message>>,
}

/// One (src, dst) FIFO. Multi-producer (endpoint + control handles of
/// one src rank), single-consumer (the dst rank's endpoint).
struct Ring {
    slots: Box<[Slot]>,
    /// Consumer cursor. Only the consumer writes it, so a plain store
    /// suffices; producers never read it (fullness is detected via the
    /// slot sequence words).
    head: AtomicUsize,
    tail: AtomicUsize,
}

// SAFETY: slot payload access is guarded by the `seq` protocol — a
// producer writes `msg` only between winning the tail CAS and releasing
// `seq = pos + 1`; the single consumer reads it only after acquiring
// that store. No two parties touch a slot's cell concurrently.
unsafe impl Send for Ring {}
unsafe impl Sync for Ring {}

impl Ring {
    fn new(cap: usize) -> Self {
        assert!(cap.is_power_of_two());
        Ring {
            slots: (0..cap)
                .map(|i| Slot { seq: AtomicUsize::new(i), msg: UnsafeCell::new(None) })
                .collect(),
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
        }
    }

    /// Multi-producer push; `Err(m)` returns ownership when full.
    fn push(&self, m: Message) -> Result<(), Message> {
        let mask = self.slots.len() - 1;
        let mut pos = self.tail.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = seq.wrapping_sub(pos) as isize;
            if dif == 0 {
                match self.tail.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: we own this slot until the seq release.
                        unsafe { *slot.msg.get() = Some(m) };
                        slot.seq.store(pos.wrapping_add(1), Ordering::Release);
                        return Ok(());
                    }
                    Err(cur) => pos = cur,
                }
            } else if dif < 0 {
                return Err(m); // a full lap behind: ring is full
            } else {
                pos = self.tail.load(Ordering::Relaxed);
            }
        }
    }

    /// Whether the head slot holds a message (consumer only).
    fn head_full(&self) -> bool {
        let mask = self.slots.len() - 1;
        let pos = self.head.load(Ordering::Relaxed);
        let seq = self.slots[pos & mask].seq.load(Ordering::Acquire);
        seq.wrapping_sub(pos.wrapping_add(1)) as isize >= 0
    }

    /// Send stamp of the head message, if any (consumer only).
    fn peek_ready_at(&self) -> Option<Instant> {
        if !self.head_full() {
            return None;
        }
        let mask = self.slots.len() - 1;
        let pos = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[pos & mask];
        // SAFETY: head_full acquired `seq == pos + 1`, so the producer's
        // write is visible and no other party touches the slot until the
        // (single) consumer advances past it.
        unsafe { (*slot.msg.get()).as_ref().map(|m| m.ready_at) }
    }

    /// Pop the head message (consumer only).
    fn pop(&self) -> Option<Message> {
        if !self.head_full() {
            return None;
        }
        let mask = self.slots.len() - 1;
        let pos = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[pos & mask];
        // SAFETY: see peek_ready_at.
        let m = unsafe { (*slot.msg.get()).take() };
        self.head.store(pos.wrapping_add(1), Ordering::Relaxed);
        // hand the slot back to producers, one lap ahead
        slot.seq.store(pos.wrapping_add(mask).wrapping_add(1), Ordering::Release);
        m
    }
}

struct ShmState {
    n: usize,
    /// `rings[src * n + dst]`.
    rings: Box<[Ring]>,
    rank_state: Box<[AtomicUsize]>,
    world_open: AtomicBool,
}

impl ShmState {
    fn ring(&self, src: usize, dst: usize) -> &Ring {
        &self.rings[src * self.n + dst]
    }

    /// Whether a message for `dst` can still be consumed — its endpoint
    /// is live, or not yet taken from a still-open world.
    fn dst_reachable(&self, dst: usize) -> bool {
        match self.rank_state[dst].load(Ordering::Acquire) {
            LIVE => true,
            UNTAKEN => self.world_open.load(Ordering::Acquire),
            _ => false,
        }
    }

    /// Shared send path (endpoint + control handles): FIFO push with
    /// bounded backpressure, dead letter once `dst` is unreachable.
    fn send(&self, dst: usize, m: Message) -> bool {
        let src = m.src;
        if dst == src {
            return true; // self-send: dropped by design, not a dead peer
        }
        let mut m = m;
        loop {
            if !self.dst_reachable(dst) {
                return false;
            }
            match self.ring(src, dst).push(m) {
                Ok(()) => return true,
                Err(back) => {
                    m = back;
                    std::thread::yield_now();
                }
            }
        }
    }
}

pub struct ShmWorld {
    state: Arc<ShmState>,
}

impl ShmWorld {
    pub fn new(n: usize) -> Self {
        let state = ShmState {
            n,
            rings: (0..n * n).map(|_| Ring::new(RING_CAP)).collect(),
            rank_state: (0..n).map(|_| AtomicUsize::new(UNTAKEN)).collect(),
            world_open: AtomicBool::new(true),
        };
        ShmWorld { state: Arc::new(state) }
    }
}

impl Drop for ShmWorld {
    fn drop(&mut self) {
        // mirrors dropping the channel world's spare sender clones
        self.state.world_open.store(false, Ordering::Release);
    }
}

impl TransportWorld for ShmWorld {
    fn size(&self) -> usize {
        self.state.n
    }

    fn take(&mut self, rank: usize) -> Box<dyn Transport> {
        let prev = self.state.rank_state[rank].compare_exchange(
            UNTAKEN,
            LIVE,
            Ordering::AcqRel,
            Ordering::Acquire,
        );
        assert!(prev.is_ok(), "endpoint already taken");
        Box::new(ShmTransport { rank, state: Arc::clone(&self.state) })
    }

    fn control_sender(&self, _rank: usize) -> Box<dyn TransportSender> {
        // routes on Message::src, so no per-rank state is needed
        Box::new(ShmSender { state: Arc::clone(&self.state) })
    }
}

pub struct ShmTransport {
    rank: usize,
    state: Arc<ShmState>,
}

impl ShmTransport {
    /// Pop the globally-earliest head across this rank's inbound rings
    /// (send-stamp order; lowest src breaks exact ties via scan order).
    fn pop_earliest(&self) -> Option<Message> {
        let me = self.rank;
        let mut best: Option<(Instant, usize)> = None;
        for src in 0..self.state.n {
            if src == me {
                continue;
            }
            if let Some(t) = self.state.ring(src, me).peek_ready_at() {
                if best.map(|(bt, _)| t < bt).unwrap_or(true) {
                    best = Some((t, src));
                }
            }
        }
        best.and_then(|(_, src)| self.state.ring(src, me).pop())
    }

    /// Disconnected ⇔ the world and every peer endpoint are gone and the
    /// inbound rings are drained — exactly when an mpsc receiver with a
    /// `None` self-slot would report disconnection.
    fn disconnected(&self) -> bool {
        if self.state.world_open.load(Ordering::Acquire) {
            return false;
        }
        for src in 0..self.state.n {
            if src == self.rank {
                continue;
            }
            if self.state.rank_state[src].load(Ordering::Acquire) == LIVE {
                return false;
            }
            if self.state.ring(src, self.rank).head_full() {
                return false;
            }
        }
        true
    }
}

impl Transport for ShmTransport {
    fn send(&self, dst: usize, m: Message) -> bool {
        self.state.send(dst, m)
    }

    fn try_recv(&mut self) -> Option<Message> {
        self.pop_earliest()
    }

    fn recv_deadline(&mut self, deadline: Instant) -> Result<Message, RecvError> {
        if let Some(m) = transport::spin_then(|| self.pop_earliest()) {
            return Ok(m);
        }
        loop {
            if let Some(m) = self.pop_earliest() {
                return Ok(m);
            }
            if self.disconnected() {
                return Err(RecvError::Disconnected);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(RecvError::Timeout);
            }
            std::thread::sleep((deadline - now).min(PARK_NAP));
        }
    }
}

impl Drop for ShmTransport {
    fn drop(&mut self) {
        self.state.rank_state[self.rank].store(DROPPED, Ordering::Release);
        // Free undelivered traffic now (the channel backend frees it when
        // the receiver drops); producers racing this drain observe the
        // DROPPED state on their next liveness check.
        while self.pop_earliest().is_some() {}
    }
}

pub struct ShmSender {
    state: Arc<ShmState>,
}

impl TransportSender for ShmSender {
    fn send(&self, dst: usize, m: Message) -> bool {
        self.state.send(dst, m)
    }
}
