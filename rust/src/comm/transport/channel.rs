//! The original `std::sync::mpsc` backend: one unbounded channel per
//! rank, senders cloned per peer. This is the default transport and is
//! bit-identical in behavior to the pre-trait bus — disconnection is the
//! channel's own (`recv` errors once every `Sender` clone is dropped),
//! and a send to a dropped endpoint fails at `Sender::send`.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Instant;

use crate::comm::bus::{Message, RecvError};
use crate::comm::transport::{Transport, TransportSender, TransportWorld};

pub struct ChannelWorld {
    senders: Vec<Sender<Message>>,
    receivers: Vec<Option<Receiver<Message>>>,
}

impl ChannelWorld {
    pub fn new(n: usize) -> Self {
        let mut senders = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(Some(rx));
        }
        ChannelWorld { senders, receivers }
    }

    /// Sender set for `rank`: the slot for the rank's own channel is
    /// `None` (self-sends are dropped by design), so disconnection — all
    /// peers + World dropped — stays observable on the rank's receiver.
    fn senders_for(&self, rank: usize) -> Vec<Option<Sender<Message>>> {
        self.senders
            .iter()
            .enumerate()
            .map(|(i, s)| if i == rank { None } else { Some(s.clone()) })
            .collect()
    }
}

impl TransportWorld for ChannelWorld {
    fn size(&self) -> usize {
        self.senders.len()
    }

    fn take(&mut self, rank: usize) -> Box<dyn Transport> {
        let rx = self.receivers[rank].take().expect("endpoint already taken");
        Box::new(ChannelTransport { rx, senders: self.senders_for(rank) })
    }

    fn control_sender(&self, rank: usize) -> Box<dyn TransportSender> {
        Box::new(ChannelSender { senders: self.senders_for(rank) })
    }
}

fn channel_send(senders: &[Option<Sender<Message>>], dst: usize, m: Message) -> bool {
    match &senders[dst] {
        Some(tx) => tx.send(m).is_ok(),
        None => true, // self-send: dropped by design, not a dead peer
    }
}

pub struct ChannelTransport {
    rx: Receiver<Message>,
    senders: Vec<Option<Sender<Message>>>,
}

impl Transport for ChannelTransport {
    fn send(&self, dst: usize, m: Message) -> bool {
        channel_send(&self.senders, dst, m)
    }

    fn try_recv(&mut self) -> Option<Message> {
        self.rx.try_recv().ok()
    }

    fn recv_deadline(&mut self, deadline: Instant) -> Result<Message, RecvError> {
        // No spin phase here: the endpoint already ran `spin_then` over
        // its mailbox before parking, and `mpsc` blocks efficiently.
        match self.rx.recv_timeout(deadline.saturating_duration_since(Instant::now())) {
            Ok(m) => Ok(m),
            Err(RecvTimeoutError::Timeout) => Err(RecvError::Timeout),
            Err(RecvTimeoutError::Disconnected) => Err(RecvError::Disconnected),
        }
    }
}

pub struct ChannelSender {
    senders: Vec<Option<Sender<Message>>>,
}

impl TransportSender for ChannelSender {
    fn send(&self, dst: usize, m: Message) -> bool {
        channel_send(&self.senders, dst, m)
    }
}
