//! MPI-work-alike message passing substrate.
//!
//! PAL (the paper) runs every kernel instance as an MPI process and moves
//! data as 1-D numpy arrays. This module reproduces that model in-process:
//! a [`World`] of `n` ranks, one [`Endpoint`] per rank (owned by that
//! kernel's host thread), tagged point-to-point messages with MPI-style
//! matching (`recv(src, tag)`), non-blocking probes (the paper's
//! `req_data.Test()`), and the collective patterns the controller uses
//! (broadcast / gather / scatter).
//!
//! Payloads are flat 1-D f32 arrays — exactly the paper's convention ("data
//! transferred among kernels should be arranged as 1-D Numpy numerical
//! arrays"). Structured data (lists of arrays, labeled pairs) is packed
//! with [`codec`].
//!
//! ## Zero-copy payload model
//!
//! Wire payloads are [`bus::Payload`]s: immutable `Arc<[f32]>` buffers.
//! The rules for when a send copies vs. shares:
//!
//! * **Sharing (free):** sending a `Payload` or `&Payload` — including
//!   re-sending a received `Message::data` on a relay hop — is a refcount
//!   bump. [`bus::Endpoint::bcast`] converts its argument at most once and
//!   then shares, so broadcasting weights to *n* shard replicas or a batch
//!   frame to a whole committee costs one buffer regardless of *n*.
//! * **Ingest (one copy):** sending owned/borrowed data (`Vec<f32>`,
//!   `&[f32]`) copies it into shared storage exactly once at the bus
//!   boundary, no matter how many destinations receive it.
//! * **Never:** the transport itself never copies per destination.
//!
//! [`bus::WorldStats`] makes the distinction observable: `messages` /
//! `payload_bytes` count *logical* traffic (a broadcast to 8 ranks counts 8
//! messages and 8× the bytes), while `payload_clones` / `bytes_copied`
//! count *physical* buffer materializations (the same broadcast counts one
//! ingest — or zero, if the caller passed an existing `Payload`). Watching
//! `bytes_copied` stay flat while `payload_bytes` scales with fan-out is
//! the zero-copy invariant, pinned by the bus unit tests and measured by
//! the `comm_overhead` bench (`BENCH_comm.json`).
//!
//! On the codec side, the *encode* half of every relay hop is
//! allocation-free in steady state: [`codec::PackBuffer`] and the `*_into`
//! encoders re-encode into reusable scratch space, and the packed scratch
//! converts into one shared payload per hop (the single ingest copy). The
//! *decode* half offers borrowed views ([`codec::unpack_views`] and the
//! datapoint/batch-frame variants in [`codec`]/[`protocol`]) that split a
//! payload into subslices of the received buffer; they are the single
//! parse path under the owned decoders.
//!
//! ## Flat data plane (Payload → BatchView → strided reduction)
//!
//! Uniform-width traffic — the steady state for stacked generator inputs
//! and committee outputs — never leaves contiguous storage between the
//! wire and the reduction:
//!
//! 1. a received [`bus::Payload`] parses with **zero allocations** into a
//!    strided [`crate::data::batch::BatchView`] ([`codec::unpack_uniform`],
//!    [`protocol::decode_predict_batch_rows`]); committee replies are
//!    retained as [`crate::data::batch::PayloadBatch`]es — refcounted
//!    slices of the frame payload — until the whole batch reduces;
//! 2. models consume the view and produce one contiguous
//!    [`crate::data::batch::RowBlock`] (`Model::predict_batch`; uniform
//!    rows in practice), and the committee reductions
//!    (`committee_std_batch` & friends) run single-pass strided loops over
//!    `&[BatchView]` with zero inner-loop allocations;
//! 3. checked results convert once into a shared payload and scatter to
//!    their generators as [`bus::Payload::slice`] row views — n refcount
//!    bumps over one allocation.
//!
//! Ragged traffic (mixed row widths) still flows through the nested-`Vec`
//! decoders/checks as a fallback; both encoders write identical wire
//! bytes, so flat and nested endpoints interoperate frame-for-frame. The
//! allocation bound — decode + committee reduce allocates a small constant
//! independent of batch size — is pinned by `rust/tests/test_flat_plane.rs`
//! (counting allocator) and measured per item in `BENCH_alloc.json`.
//! Control messages ride the `OnceLock`-cached [`bus::Payload::empty`], so
//! stop/shutdown fan-outs allocate nothing at all.
//!
//! ## Flat training plane (oracle → retrain, weights → replicas)
//!
//! The training side mirrors the prediction plane end to end:
//!
//! 1. an oracle result's `(input, label)` views copy straight from the
//!    received payload into the Manager's contiguous
//!    [`crate::data::batch::DatapointBlock`] staging buffer — no per-sample
//!    `(Vec, Vec)` boxing;
//! 2. a retrain flush encodes the whole block with
//!    [`codec::encode_train_block_into`] (wire bytes identical to the
//!    nested `pack_datapoints`) into a reusable scratch and broadcasts one
//!    shared payload to every trainer;
//! 3. the train host decodes with [`codec::decode_train_block_views`] —
//!    borrowed pair views over the payload, one bounds-list allocation —
//!    and hands them to `Model::add_trainingset_batch`, whose native
//!    implementations stage the rows contiguously (O(1) allocations per
//!    flush, pinned by `rust/tests/test_flat_train.rs`);
//! 4. weight syncs ship one shared payload per round
//!    (`Model::get_weight_payload` → [`bus::Endpoint::bcast`]) that every
//!    shard replica *adopts* by refcount (`Model::update_from`) — zero
//!    per-destination copies, proven by [`bus::WorldStats`] in the
//!    regression tests and measured in `BENCH_train.json`.
//!
//! Receive-side gathers are *vectored*: [`bus::Endpoint::recv_ready_all`]
//! drains a whole per-tag mailbox in one pass, so a lockstep round (or a
//! committee gather) costs one wake-up per round instead of one per
//! source; early next-round traffic is requeued at the mailbox front
//! ([`bus::Endpoint::requeue_front`]), preserving per-(src, tag) FIFO.
//!
//! Receive-side matching is indexed: each endpoint files unmatched messages
//! into per-tag mailboxes, so `recv(src, tag)` inspects only its own tag's
//! queue — O(1) amortized per message — instead of rescanning all queued
//! traffic as the old single-queue matcher did.
//!
//! For the speedup/overhead benches a per-message latency can be injected
//! ([`World::with_latency`]); messages only become visible to `recv` after
//! their simulated arrival time, modeling a real interconnect without
//! blocking the sender.
//!
//! Beyond the paper's per-rank payloads, [`protocol`] defines two batch
//! frames for the batched exchange mode: `PredictBatch`
//! ([`protocol::TAG_PRED_BATCH`]) carries a micro-batch of inputs coalesced
//! from several generators to one prediction shard, and
//! `PredictBatchResult` ([`protocol::TAG_PRED_BATCH_RESULT`]) carries the
//! per-item outputs back, echoing the batch id. Both are self-describing
//! (`[id_hi, id_lo, packed item list]`), so no size headers are needed even
//! in `fixed_size_data = false` mode, and one frame replaces what the
//! unbatched relay pays per item.
//!
//! ## Oracle-plane frames (green flow)
//!
//! The batched oracle mode rides the same frame discipline: `OracleBatch`
//! ([`protocol::TAG_ORACLE_BATCH`], layout identical to `PredictBatch`)
//! carries a micro-batch of Manager-selected inputs to one oracle, and
//! `OracleLabels` ([`protocol::TAG_ORACLE_LABELS`], layout identical to
//! `PredictBatchResult`) returns *only the labels* under the echoed id —
//! the Manager retains each dispatched input block keyed by batch id and
//! pairs label row `i` with retained input row `i`, so the inputs never
//! travel back over the wire (roughly halving green-flow result bytes at
//! typical label widths). The legacy interleaved layout
//! (`OracleBatchResult`, [`protocol::TAG_ORACLE_BATCH_RESULT`], packed
//! section byte-identical to `pack_datapoints` over the `(input, label)`
//! pairs) is still decoded for mixed-version runs. The per-label leg
//! ([`protocol::TAG_TO_ORACLE`] / [`protocol::TAG_ORACLE_RESULT`]) is
//! unchanged on the wire; all legs produce bit-identical labels.
//!
//! ## Fault model
//!
//! The bus assumes a host can die at any bus operation (panic, injected
//! [`fault::FaultKill`]) and makes the failure *observable* rather than
//! silent:
//!
//! * **Send to a dead rank** — [`bus::Endpoint::send`] returns `false` and
//!   the loss is counted in [`bus::WorldStats::dead_letters`];
//!   [`bus::Endpoint::bcast`] reports how many destinations accepted.
//!   During the shutdown drain dead letters are benign (drain discipline);
//!   mid-run they are the liveness signal the coordinator reacts to.
//! * **Supervised death** — every workflow host runs under `catch_unwind`;
//!   the supervisor announces the dead rank on
//!   [`protocol::TAG_RANK_DOWN`] via a [`bus::ControlHandle`] (send-only,
//!   immune to the dead rank's own fault rules), and the Manager/Exchange
//!   evict the rank and requeue its in-flight work.
//! * **Deterministic injection** — a [`fault::FaultPlan`] installed with
//!   [`bus::World::set_fault_plan`] compiles per rank and triggers on
//!   protocol events (Nth send/arrival) or injected time, so chaos runs
//!   replay exactly; the empty plan compiles to nothing and clean runs are
//!   bit-identical.
//!
//! What the system tolerates, what degrades, and what aborts is documented
//! at the crate root (`lib.rs`, "Fault plane").
//!
//! ## Transport plane
//!
//! Everything above — mailboxes, matching, latency, faults, stats — is
//! protocol; *delivery* is a pluggable backend behind the
//! [`transport::Transport`] trait ([`transport::TransportKind`] selects it
//! per run via the `transport` config key or `pal run --transport=...`):
//!
//! * [`transport::channel`] — the original `std::sync::mpsc` bus
//!   (default; bit-identical to the pre-trait behavior).
//! * [`transport::shm`] — lock-free shared-memory-style rings, one per
//!   (src, dst) rank pair; buffer ownership is handed off on send, so the
//!   hot path has no mutex and no per-message channel-node allocation.
//! * [`transport::tcp`] — length-prefixed framed sockets over `std::net`
//!   for true multi-process worlds, bootstrapped with
//!   [`bus::World::listen`] / [`bus::World::connect`]; payload bytes are
//!   serialized only at the process boundary and charged to
//!   [`bus::WorldStats::bytes_copied`].
//!
//! Because the backends slot in *under* the mailbox layer, the zero-copy
//! payload model, fault injection, injected latency, and dead-letter
//! accounting apply to all of them unchanged; the cross-backend conformance
//! suite (`rust/tests/test_transport.rs`) pins that contract, including
//! bit-identical active-learning runs across the in-process backends.
//!
//! ## Live observability
//!
//! During an observed run (`pal run --metrics-addr=...`) the workflow
//! hands the run's [`bus::WorldStats`] to the live metrics registry
//! ([`crate::telemetry::registry`]): `/metrics` exports the same
//! logical-vs-physical counters as `pal_world_*` series
//! (`pal_world_messages_total`, `pal_world_payload_bytes_total`,
//! `pal_world_bytes_copied_total`, `pal_world_dead_letters_total`, …)
//! and `/status` embeds them as the `world` object — so the zero-copy
//! invariant (`bytes_copied` flat while `payload_bytes` scales with
//! fan-out) is scrapeable mid-run instead of only visible in the final
//! `RunReport`. The crate-root docs ("Observability plane") describe the
//! full surface, metric naming scheme, and trace span taxonomy.

pub mod bus;
pub mod codec;
pub mod fault;
pub mod protocol;
pub mod transport;

pub use bus::{ControlHandle, Endpoint, Message, Payload, PayloadId, RecvError, World};
pub use fault::{FaultKill, FaultPlan};
pub use transport::TransportKind;
