//! MPI-work-alike message passing substrate.
//!
//! PAL (the paper) runs every kernel instance as an MPI process and moves
//! data as 1-D numpy arrays. This module reproduces that model in-process:
//! a [`World`] of `n` ranks, one [`Endpoint`] per rank (owned by that
//! kernel's host thread), tagged point-to-point messages with MPI-style
//! matching (`recv(src, tag)`), non-blocking probes (the paper's
//! `req_data.Test()`), and the collective patterns the controller uses
//! (broadcast / gather / scatter).
//!
//! Payloads are flat `Vec<f32>` — exactly the paper's convention ("data
//! transferred among kernels should be arranged as 1-D Numpy numerical
//! arrays"). Structured data (lists of arrays, labeled pairs) is packed
//! with [`codec`].
//!
//! For the speedup/overhead benches a per-message latency can be injected
//! ([`World::with_latency`]); messages only become visible to `recv` after
//! their simulated arrival time, modeling a real interconnect without
//! blocking the sender.
//!
//! Beyond the paper's per-rank payloads, [`protocol`] defines two batch
//! frames for the batched exchange mode: `PredictBatch`
//! ([`protocol::TAG_PRED_BATCH`]) carries a micro-batch of inputs coalesced
//! from several generators to one prediction shard, and
//! `PredictBatchResult` ([`protocol::TAG_PRED_BATCH_RESULT`]) carries the
//! per-item outputs back, echoing the batch id. Both are self-describing
//! (`[id_hi, id_lo, packed item list]`), so no size headers are needed even
//! in `fixed_size_data = false` mode, and one frame replaces what the
//! unbatched relay pays per item.

pub mod bus;
pub mod codec;
pub mod protocol;

pub use bus::{Endpoint, Message, RecvError, World};
