//! Packing structured data into flat f32 payloads.
//!
//! The paper fixes the MPI wire format to 1-D numerical arrays; anything
//! structured (a list of per-generator arrays, an (input, label) pair, a
//! batch of labeled datapoints) is packed with a small numeric header:
//!
//! ```text
//! [ count, len_0, len_1, ..., len_{count-1}, data_0..., data_1..., ... ]
//! ```
//!
//! Lengths are exact in f32 up to 2^24 elements — far beyond any message
//! here; [`pack`] asserts the bound. This mirrors the paper's
//! `fixed_size_data=False` mode where "sizes of data are passed first for
//! every MPI communication" (§S3), just fused into one message.
//!
//! ## Allocation discipline
//!
//! The borrowed view API ([`unpack_views`], [`unpack_datapoint_views`])
//! splits a packed payload into subslices of the original buffer — no
//! per-part allocation — and is the single parse path: the owned variants
//! ([`unpack`], [`unpack_datapoints`]) are thin copies on top, so the two
//! accept and reject exactly the same inputs. On the encode side,
//! [`pack_into`] appends to a caller-owned buffer and [`PackBuffer`] wraps
//! one for reuse, so hot relay loops re-encode every round without a fresh
//! heap allocation.

/// Maximum exactly-representable length in an f32 header.
pub const MAX_LEN: usize = 1 << 24;

/// Append the packed encoding of `parts` to `out` (no clear; composable
/// with frame headers). Accepts any slice-of-slice-like list:
/// `&[&[f32]]`, `&[Vec<f32>]`, `&[Payload]`, ...
pub fn pack_into<S: AsRef<[f32]>>(parts: &[S], out: &mut Vec<f32>) {
    assert!(parts.len() < MAX_LEN, "too many parts");
    let total: usize = parts.iter().map(|p| p.as_ref().len()).sum();
    out.reserve(1 + parts.len() + total);
    out.push(parts.len() as f32);
    for p in parts {
        assert!(p.as_ref().len() < MAX_LEN, "part too long for f32 header");
        out.push(p.as_ref().len() as f32);
    }
    for p in parts {
        out.extend_from_slice(p.as_ref());
    }
}

/// Pack a list of arrays into one flat payload.
pub fn pack(parts: &[&[f32]]) -> Vec<f32> {
    let mut out = Vec::new();
    pack_into(parts, &mut out);
    out
}

/// Pack a list of owned arrays.
pub fn pack_vecs(parts: &[Vec<f32>]) -> Vec<f32> {
    let mut out = Vec::new();
    pack_into(parts, &mut out);
    out
}

/// Reusable packing scratch. Each [`PackBuffer::pack`] clears and refills
/// one internal buffer, so steady-state re-encoding on a relay hop costs
/// zero allocations; the returned view is valid until the next call.
#[derive(Debug, Default)]
pub struct PackBuffer {
    buf: Vec<f32>,
}

impl PackBuffer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pack `parts` into the internal buffer and return a view of it.
    pub fn pack<S: AsRef<[f32]>>(&mut self, parts: &[S]) -> &[f32] {
        self.buf.clear();
        pack_into(parts, &mut self.buf);
        &self.buf
    }

    /// Pack labeled datapoints (view-typed twin of [`pack_datapoints`]).
    pub fn pack_datapoints(&mut self, points: &[(Vec<f32>, Vec<f32>)]) -> &[f32] {
        let parts = datapoint_parts(points);
        self.buf.clear();
        pack_into(&parts, &mut self.buf);
        &self.buf
    }

    /// Current scratch capacity (diagnostics: should plateau on hot loops).
    pub fn capacity(&self) -> usize {
        self.buf.capacity()
    }
}

/// Split a payload produced by [`pack`] into borrowed part views — zero
/// copies, zero per-part allocations. Returns `None` on malformed input;
/// the acceptance set is identical to [`unpack`] by construction (the owned
/// variant is implemented on top of this).
pub fn unpack_views(data: &[f32]) -> Option<Vec<&[f32]>> {
    let count = *data.first()? as usize;
    if count >= MAX_LEN {
        return None;
    }
    let mut lens = Vec::with_capacity(count);
    for i in 0..count {
        let l = *data.get(1 + i)? as usize;
        if l >= MAX_LEN {
            return None;
        }
        lens.push(l);
    }
    let mut off = 1 + count;
    let mut out = Vec::with_capacity(count);
    for l in lens {
        let end = off.checked_add(l)?;
        out.push(data.get(off..end)?);
        off = end;
    }
    if off != data.len() {
        return None; // trailing garbage
    }
    Some(out)
}

/// Unpack a payload produced by [`pack`]. Returns `None` on malformed input.
pub fn unpack(data: &[f32]) -> Option<Vec<Vec<f32>>> {
    Some(unpack_views(data)?.into_iter().map(|s| s.to_vec()).collect())
}

fn datapoint_parts(points: &[(Vec<f32>, Vec<f32>)]) -> Vec<&[f32]> {
    let mut parts: Vec<&[f32]> = Vec::with_capacity(points.len() * 2);
    for (x, y) in points {
        parts.push(x);
        parts.push(y);
    }
    parts
}

/// Pack labeled datapoints `[(input, label), ...]` (the yellow flow of
/// Fig. 4: controller → training kernel).
pub fn pack_datapoints(points: &[(Vec<f32>, Vec<f32>)]) -> Vec<f32> {
    pack(&datapoint_parts(points))
}

/// Borrowed-view inverse of [`pack_datapoints`]: `(input, label)` subslice
/// pairs into the original buffer.
pub fn unpack_datapoint_views(data: &[f32]) -> Option<Vec<(&[f32], &[f32])>> {
    let parts = unpack_views(data)?;
    if parts.len() % 2 != 0 {
        return None;
    }
    Some(parts.chunks_exact(2).map(|pair| (pair[0], pair[1])).collect())
}

/// Inverse of [`pack_datapoints`].
pub fn unpack_datapoints(data: &[f32]) -> Option<Vec<(Vec<f32>, Vec<f32>)>> {
    Some(
        unpack_datapoint_views(data)?
            .into_iter()
            .map(|(x, y)| (x.to_vec(), y.to_vec()))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let a = vec![1.0, 2.0];
        let b = vec![3.0];
        let c: Vec<f32> = vec![];
        let packed = pack(&[&a, &b, &c]);
        assert_eq!(unpack(&packed).unwrap(), vec![a, b, c]);
    }

    #[test]
    fn roundtrip_empty_list() {
        let packed = pack(&[]);
        assert_eq!(unpack(&packed).unwrap(), Vec::<Vec<f32>>::new());
    }

    #[test]
    fn rejects_truncated() {
        let packed = pack(&[&[1.0, 2.0, 3.0]]);
        assert!(unpack(&packed[..packed.len() - 1]).is_none());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut packed = pack(&[&[1.0]]);
        packed.push(9.0);
        assert!(unpack(&packed).is_none());
    }

    #[test]
    fn rejects_empty_input() {
        assert!(unpack(&[]).is_none());
    }

    #[test]
    fn views_are_subslices_of_input() {
        let a = vec![1.0, 2.0];
        let b: Vec<f32> = vec![];
        let c = vec![3.0, 4.0, 5.0];
        let packed = pack(&[&a, &b, &c]);
        let views = unpack_views(&packed).unwrap();
        assert_eq!(views, vec![&a[..], &b[..], &c[..]]);
        // views alias the packed buffer, not fresh allocations
        let base = packed.as_ptr() as usize;
        let end = base + packed.len() * std::mem::size_of::<f32>();
        for v in &views {
            if !v.is_empty() {
                let p = v.as_ptr() as usize;
                assert!(p >= base && p < end, "view escapes the packed buffer");
            }
        }
    }

    #[test]
    fn pack_buffer_reuses_allocation() {
        let mut buf = PackBuffer::new();
        let parts: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32; 32]).collect();
        let first = buf.pack(&parts).to_vec();
        assert_eq!(unpack(&first).unwrap(), parts);
        let cap = buf.capacity();
        for _ in 0..10 {
            let packed = buf.pack(&parts);
            assert_eq!(packed, first.as_slice());
        }
        assert_eq!(buf.capacity(), cap, "steady-state packing must not reallocate");
    }

    #[test]
    fn datapoints_roundtrip() {
        let pts = vec![
            (vec![1.0, 2.0], vec![0.5]),
            (vec![3.0], vec![0.25, 0.75]),
        ];
        let packed = pack_datapoints(&pts);
        assert_eq!(unpack_datapoints(&packed).unwrap(), pts);
        let views = unpack_datapoint_views(&packed).unwrap();
        assert_eq!(views.len(), 2);
        assert_eq!(views[0], (&pts[0].0[..], &pts[0].1[..]));
        assert_eq!(views[1], (&pts[1].0[..], &pts[1].1[..]));
    }

    #[test]
    fn datapoints_odd_parts_rejected() {
        let packed = pack(&[&[1.0], &[2.0], &[3.0]]); // 3 parts: not pairs
        assert!(unpack_datapoints(&packed).is_none());
        assert!(unpack_datapoint_views(&packed).is_none());
    }

    #[test]
    fn large_payload_roundtrip() {
        let big: Vec<f32> = (0..100_000).map(|i| i as f32).collect();
        let packed = pack(&[&big]);
        let got = unpack(&packed).unwrap();
        assert_eq!(got[0], big);
    }
}
