//! Packing structured data into flat f32 payloads.
//!
//! The paper fixes the MPI wire format to 1-D numerical arrays; anything
//! structured (a list of per-generator arrays, an (input, label) pair, a
//! batch of labeled datapoints) is packed with a small numeric header:
//!
//! ```text
//! [ count, len_0, len_1, ..., len_{count-1}, data_0..., data_1..., ... ]
//! ```
//!
//! Lengths are exact in f32 up to 2^24 elements — far beyond any message
//! here; [`pack`] asserts the bound. This mirrors the paper's
//! `fixed_size_data=False` mode where "sizes of data are passed first for
//! every MPI communication" (§S3), just fused into one message.

/// Maximum exactly-representable length in an f32 header.
pub const MAX_LEN: usize = 1 << 24;

/// Pack a list of arrays into one flat payload.
pub fn pack(parts: &[&[f32]]) -> Vec<f32> {
    assert!(parts.len() < MAX_LEN, "too many parts");
    let total: usize = parts.iter().map(|p| p.len()).sum();
    let mut out = Vec::with_capacity(1 + parts.len() + total);
    out.push(parts.len() as f32);
    for p in parts {
        assert!(p.len() < MAX_LEN, "part too long for f32 header");
        out.push(p.len() as f32);
    }
    for p in parts {
        out.extend_from_slice(p);
    }
    out
}

/// Pack a list of owned arrays.
pub fn pack_vecs(parts: &[Vec<f32>]) -> Vec<f32> {
    pack(&parts.iter().map(|p| p.as_slice()).collect::<Vec<_>>())
}

/// Unpack a payload produced by [`pack`]. Returns `None` on malformed input.
pub fn unpack(data: &[f32]) -> Option<Vec<Vec<f32>>> {
    let count = *data.first()? as usize;
    if count >= MAX_LEN {
        return None;
    }
    let mut lens = Vec::with_capacity(count);
    for i in 0..count {
        let l = *data.get(1 + i)? as usize;
        if l >= MAX_LEN {
            return None;
        }
        lens.push(l);
    }
    let mut off = 1 + count;
    let mut out = Vec::with_capacity(count);
    for l in lens {
        let end = off.checked_add(l)?;
        out.push(data.get(off..end)?.to_vec());
        off = end;
    }
    if off != data.len() {
        return None; // trailing garbage
    }
    Some(out)
}

/// Pack labeled datapoints `[(input, label), ...]` (the yellow flow of
/// Fig. 4: controller → training kernel).
pub fn pack_datapoints(points: &[(Vec<f32>, Vec<f32>)]) -> Vec<f32> {
    let mut parts: Vec<&[f32]> = Vec::with_capacity(points.len() * 2);
    for (x, y) in points {
        parts.push(x);
        parts.push(y);
    }
    pack(&parts)
}

/// Inverse of [`pack_datapoints`].
pub fn unpack_datapoints(data: &[f32]) -> Option<Vec<(Vec<f32>, Vec<f32>)>> {
    let parts = unpack(data)?;
    if parts.len() % 2 != 0 {
        return None;
    }
    let mut out = Vec::with_capacity(parts.len() / 2);
    let mut it = parts.into_iter();
    while let (Some(x), Some(y)) = (it.next(), it.next()) {
        out.push((x, y));
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let a = vec![1.0, 2.0];
        let b = vec![3.0];
        let c: Vec<f32> = vec![];
        let packed = pack(&[&a, &b, &c]);
        assert_eq!(unpack(&packed).unwrap(), vec![a, b, c]);
    }

    #[test]
    fn roundtrip_empty_list() {
        let packed = pack(&[]);
        assert_eq!(unpack(&packed).unwrap(), Vec::<Vec<f32>>::new());
    }

    #[test]
    fn rejects_truncated() {
        let packed = pack(&[&[1.0, 2.0, 3.0]]);
        assert!(unpack(&packed[..packed.len() - 1]).is_none());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut packed = pack(&[&[1.0]]);
        packed.push(9.0);
        assert!(unpack(&packed).is_none());
    }

    #[test]
    fn rejects_empty_input() {
        assert!(unpack(&[]).is_none());
    }

    #[test]
    fn datapoints_roundtrip() {
        let pts = vec![
            (vec![1.0, 2.0], vec![0.5]),
            (vec![3.0], vec![0.25, 0.75]),
        ];
        let packed = pack_datapoints(&pts);
        assert_eq!(unpack_datapoints(&packed).unwrap(), pts);
    }

    #[test]
    fn datapoints_odd_parts_rejected() {
        let packed = pack(&[&[1.0], &[2.0], &[3.0]]); // 3 parts: not pairs
        assert!(unpack_datapoints(&packed).is_none());
    }

    #[test]
    fn large_payload_roundtrip() {
        let big: Vec<f32> = (0..100_000).map(|i| i as f32).collect();
        let packed = pack(&[&big]);
        let got = unpack(&packed).unwrap();
        assert_eq!(got[0], big);
    }
}
