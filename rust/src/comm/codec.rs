//! Packing structured data into flat f32 payloads.
//!
//! The paper fixes the MPI wire format to 1-D numerical arrays; anything
//! structured (a list of per-generator arrays, an (input, label) pair, a
//! batch of labeled datapoints) is packed with a small numeric header:
//!
//! ```text
//! [ count, len_0, len_1, ..., len_{count-1}, data_0..., data_1..., ... ]
//! ```
//!
//! Lengths are exact in f32 up to 2^24 elements — far beyond any message
//! here; [`pack`] asserts the bound. This mirrors the paper's
//! `fixed_size_data=False` mode where "sizes of data are passed first for
//! every MPI communication" (§S3), just fused into one message.
//!
//! ## Allocation discipline
//!
//! The borrowed view API ([`unpack_views`], [`unpack_datapoint_views`])
//! splits a packed payload into subslices of the original buffer — no
//! per-part allocation — and is the single parse path: the owned variants
//! ([`unpack`], [`unpack_datapoints`]) are thin copies on top, so the two
//! accept and reject exactly the same inputs. On the encode side,
//! [`pack_into`] appends to a caller-owned buffer and [`PackBuffer`] wraps
//! one for reuse, so hot relay loops re-encode every round without a fresh
//! heap allocation.
//!
//! ## Flat data plane
//!
//! When every packed part shares one width — the common case for stacked
//! generator inputs and committee outputs — [`unpack_uniform`] parses the
//! payload with **zero** allocations (it returns `(rows, width, offset)`
//! over the original buffer) and [`unpack_batch_view`] wraps the result as
//! a strided [`BatchView`]. Ragged payloads return `None` and fall back to
//! the per-part view API. The matching encoders ([`pack_batch_into`],
//! [`pack_rows_into_buf`]) write the *same wire bytes* as [`pack_into`]
//! over nested rows, so flat and nested endpoints interoperate frame-for-
//! frame; the flat encode is a header write plus one `memcpy`.

use crate::data::batch::{BatchView, DatapointBlock, DatapointView, RowBlock};

/// Maximum exactly-representable length in an f32 header.
pub const MAX_LEN: usize = 1 << 24;

/// Append the packed encoding of `parts` to `out` (no clear; composable
/// with frame headers). Accepts any slice-of-slice-like list:
/// `&[&[f32]]`, `&[Vec<f32>]`, `&[Payload]`, ...
pub fn pack_into<S: AsRef<[f32]>>(parts: &[S], out: &mut Vec<f32>) {
    assert!(parts.len() < MAX_LEN, "too many parts");
    let total: usize = parts.iter().map(|p| p.as_ref().len()).sum();
    out.reserve(1 + parts.len() + total);
    out.push(parts.len() as f32);
    for p in parts {
        assert!(p.as_ref().len() < MAX_LEN, "part too long for f32 header");
        out.push(p.as_ref().len() as f32);
    }
    for p in parts {
        out.extend_from_slice(p.as_ref());
    }
}

/// Pack a list of arrays into one flat payload.
pub fn pack(parts: &[&[f32]]) -> Vec<f32> {
    let mut out = Vec::new();
    pack_into(parts, &mut out);
    out
}

/// Pack a list of owned arrays.
pub fn pack_vecs(parts: &[Vec<f32>]) -> Vec<f32> {
    let mut out = Vec::new();
    pack_into(parts, &mut out);
    out
}

/// Reusable packing scratch. Each [`PackBuffer::pack`] clears and refills
/// one internal buffer, so steady-state re-encoding on a relay hop costs
/// zero allocations; the returned view is valid until the next call.
#[derive(Debug, Default)]
pub struct PackBuffer {
    buf: Vec<f32>,
}

impl PackBuffer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pack `parts` into the internal buffer and return a view of it.
    pub fn pack<S: AsRef<[f32]>>(&mut self, parts: &[S]) -> &[f32] {
        self.buf.clear();
        pack_into(parts, &mut self.buf);
        &self.buf
    }

    /// Pack labeled datapoints (view-typed twin of [`pack_datapoints`]).
    pub fn pack_datapoints(&mut self, points: &[(Vec<f32>, Vec<f32>)]) -> &[f32] {
        let parts = datapoint_parts(points);
        self.buf.clear();
        pack_into(&parts, &mut self.buf);
        &self.buf
    }

    /// Pack a uniform batch (flat twin of [`PackBuffer::pack`]; identical
    /// wire bytes, one `memcpy` for the data section).
    pub fn pack_batch(&mut self, batch: &BatchView<'_>) -> &[f32] {
        self.buf.clear();
        pack_batch_into(batch, &mut self.buf);
        &self.buf
    }

    /// Pack a contiguous (possibly ragged) row block.
    pub fn pack_row_block(&mut self, rows: &RowBlock) -> &[f32] {
        self.buf.clear();
        pack_rows_into_buf(rows, &mut self.buf);
        &self.buf
    }

    /// Pack a contiguous labeled-data block (flat twin of
    /// [`PackBuffer::pack_datapoints`]; identical wire bytes).
    pub fn pack_train_block(&mut self, block: &DatapointBlock) -> &[f32] {
        self.buf.clear();
        encode_train_block_into(block, &mut self.buf);
        &self.buf
    }

    /// Current scratch capacity (diagnostics: should plateau on hot loops).
    pub fn capacity(&self) -> usize {
        self.buf.capacity()
    }
}

/// Split a payload produced by [`pack`] into borrowed part views — zero
/// copies, zero per-part allocations. Returns `None` on malformed input;
/// the acceptance set is identical to [`unpack`] by construction (the owned
/// variant is implemented on top of this).
pub fn unpack_views(data: &[f32]) -> Option<Vec<&[f32]>> {
    let count = *data.first()? as usize;
    if count >= MAX_LEN {
        return None;
    }
    let mut lens = Vec::with_capacity(count);
    for i in 0..count {
        let l = *data.get(1 + i)? as usize;
        if l >= MAX_LEN {
            return None;
        }
        lens.push(l);
    }
    let mut off = 1 + count;
    let mut out = Vec::with_capacity(count);
    for l in lens {
        let end = off.checked_add(l)?;
        out.push(data.get(off..end)?);
        off = end;
    }
    if off != data.len() {
        return None; // trailing garbage
    }
    Some(out)
}

/// Unpack a payload produced by [`pack`]. Returns `None` on malformed input.
pub fn unpack(data: &[f32]) -> Option<Vec<Vec<f32>>> {
    Some(unpack_views(data)?.into_iter().map(|s| s.to_vec()).collect())
}

/// Parse a packed payload whose parts all share one width, with **zero**
/// allocations: returns `(rows, width, data_offset)` such that
/// `&data[data_offset..]` is the contiguous `rows × width` block.
///
/// Accepts exactly the subset of [`unpack_views`]-valid payloads whose part
/// lengths are all equal (an empty list parses as `(0, 0, _)`); ragged or
/// malformed payloads return `None`.
pub fn unpack_uniform(data: &[f32]) -> Option<(usize, usize, usize)> {
    let rows = *data.first()? as usize;
    if rows >= MAX_LEN {
        return None;
    }
    let width = if rows == 0 { 0 } else { *data.get(1)? as usize };
    if width >= MAX_LEN {
        return None;
    }
    for i in 1..rows {
        if *data.get(1 + i)? as usize != width {
            return None; // ragged: defer to the per-part view API
        }
    }
    let start = 1 + rows;
    let end = start.checked_add(rows.checked_mul(width)?)?;
    if end != data.len() {
        return None; // truncated or trailing garbage
    }
    Some((rows, width, start))
}

/// [`unpack_uniform`] wrapped as a strided [`BatchView`] over the payload.
pub fn unpack_batch_view(data: &[f32]) -> Option<BatchView<'_>> {
    let (rows, width, start) = unpack_uniform(data)?;
    BatchView::from_parts(&data[start..], rows, width)
}

/// Ragged-capable header parse with a single bounds allocation: returns
/// `(ends, data_offset)` where row `i` spans
/// `data_offset + ends[i-1] .. data_offset + ends[i]` (`ends[-1]` read as
/// 0). Accepts exactly the [`unpack_views`]-valid payloads; callers that
/// hold the payload by refcount use this to build a
/// [`crate::data::batch::SharedRows`] over the data section instead of
/// boxing per-row copies.
pub fn unpack_row_ends(data: &[f32]) -> Option<(Vec<usize>, usize)> {
    let count = *data.first()? as usize;
    if count >= MAX_LEN {
        return None;
    }
    let mut ends = Vec::with_capacity(count);
    let mut total = 0usize;
    for i in 0..count {
        let l = *data.get(1 + i)? as usize;
        if l >= MAX_LEN {
            return None;
        }
        total = total.checked_add(l)?;
        ends.push(total);
    }
    let start = 1 + count;
    if start.checked_add(total)? != data.len() {
        return None; // truncated or trailing garbage
    }
    Some((ends, start))
}

/// Append the packed encoding of a uniform batch to `out` — wire-identical
/// to [`pack_into`] over the batch's rows, but the data section is one
/// `memcpy` of the flat buffer.
pub fn pack_batch_into(batch: &BatchView<'_>, out: &mut Vec<f32>) {
    let (rows, width) = (batch.rows(), batch.width());
    assert!(rows < MAX_LEN, "too many parts");
    assert!(width < MAX_LEN, "part too long for f32 header");
    out.reserve(1 + rows + batch.flat().len());
    out.push(rows as f32);
    for _ in 0..rows {
        out.push(width as f32);
    }
    out.extend_from_slice(batch.flat());
}

/// Append the packed encoding of a (possibly ragged) [`RowBlock`] to `out`
/// — wire-identical to [`pack_into`] over its rows, data section in one
/// `memcpy`.
pub fn pack_rows_into_buf(rows: &RowBlock, out: &mut Vec<f32>) {
    assert!(rows.len() < MAX_LEN, "too many parts");
    out.reserve(1 + rows.len() + rows.total_values());
    out.push(rows.len() as f32);
    for i in 0..rows.len() {
        let (s, e) = rows.bounds(i);
        assert!(e - s < MAX_LEN, "part too long for f32 header");
        out.push((e - s) as f32);
    }
    out.extend_from_slice(rows.flat());
}

fn datapoint_parts(points: &[(Vec<f32>, Vec<f32>)]) -> Vec<&[f32]> {
    let mut parts: Vec<&[f32]> = Vec::with_capacity(points.len() * 2);
    for (x, y) in points {
        parts.push(x);
        parts.push(y);
    }
    parts
}

/// Pack labeled datapoints `[(input, label), ...]` (the yellow flow of
/// Fig. 4: controller → training kernel).
pub fn pack_datapoints(points: &[(Vec<f32>, Vec<f32>)]) -> Vec<f32> {
    pack(&datapoint_parts(points))
}

/// Borrowed-view inverse of [`pack_datapoints`]: `(input, label)` subslice
/// pairs into the original buffer.
pub fn unpack_datapoint_views(data: &[f32]) -> Option<Vec<(&[f32], &[f32])>> {
    let parts = unpack_views(data)?;
    if parts.len() % 2 != 0 {
        return None;
    }
    Some(parts.chunks_exact(2).map(|pair| (pair[0], pair[1])).collect())
}

/// Inverse of [`pack_datapoints`].
pub fn unpack_datapoints(data: &[f32]) -> Option<Vec<(Vec<f32>, Vec<f32>)>> {
    Some(
        unpack_datapoint_views(data)?
            .into_iter()
            .map(|(x, y)| (x.to_vec(), y.to_vec()))
            .collect(),
    )
}

// ---------------------------------------------------------------------------
// Flat training plane (contiguous labeled-data blocks; wire bytes identical)
// ---------------------------------------------------------------------------

/// Append the packed encoding of a [`DatapointBlock`] to `out` —
/// wire-identical to [`pack_datapoints`] over the block's pairs (count
/// `2n`, interleaved `x/y` lengths, interleaved `x/y` data), but every
/// value copies straight out of the block's two flat buffers; no nested
/// pair list is ever materialized.
pub fn encode_train_block_into(block: &DatapointBlock, out: &mut Vec<f32>) {
    let n = block.len();
    assert!(2 * n < MAX_LEN, "too many parts");
    out.reserve(1 + 2 * n + block.total_input_values() + block.total_label_values());
    out.push((2 * n) as f32);
    for i in 0..n {
        let (x, y) = block.pair(i);
        assert!(x.len() < MAX_LEN && y.len() < MAX_LEN, "part too long for f32 header");
        out.push(x.len() as f32);
        out.push(y.len() as f32);
    }
    for i in 0..n {
        let (x, y) = block.pair(i);
        out.extend_from_slice(x);
        out.extend_from_slice(y);
    }
}

/// Borrowed flat-plane inverse of [`pack_datapoints`] /
/// [`encode_train_block_into`]: the whole payload parses into one
/// [`DatapointView`] whose pairs are subslices of `data` — one bounds-list
/// allocation total, independent of the point count. Accepts and rejects
/// exactly the same inputs as [`unpack_datapoint_views`] (property-tested).
pub fn decode_train_block_views(data: &[f32]) -> Option<DatapointView<'_>> {
    let count = *data.first()? as usize;
    if count >= MAX_LEN || count % 2 != 0 {
        return None;
    }
    let mut bounds = Vec::with_capacity(count / 2);
    let mut off = 1 + count;
    for i in (0..count).step_by(2) {
        let lx = *data.get(1 + i)? as usize;
        let ly = *data.get(2 + i)? as usize;
        if lx >= MAX_LEN || ly >= MAX_LEN {
            return None;
        }
        let xe = off.checked_add(lx)?;
        let ye = xe.checked_add(ly)?;
        data.get(off..xe)?;
        data.get(xe..ye)?;
        bounds.push((off, xe, xe, ye));
        off = ye;
    }
    if off != data.len() {
        return None; // truncated or trailing garbage
    }
    DatapointView::from_bounds(data, data, bounds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let a = vec![1.0, 2.0];
        let b = vec![3.0];
        let c: Vec<f32> = vec![];
        let packed = pack(&[&a, &b, &c]);
        assert_eq!(unpack(&packed).unwrap(), vec![a, b, c]);
    }

    #[test]
    fn roundtrip_empty_list() {
        let packed = pack(&[]);
        assert_eq!(unpack(&packed).unwrap(), Vec::<Vec<f32>>::new());
    }

    #[test]
    fn rejects_truncated() {
        let packed = pack(&[&[1.0, 2.0, 3.0]]);
        assert!(unpack(&packed[..packed.len() - 1]).is_none());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut packed = pack(&[&[1.0]]);
        packed.push(9.0);
        assert!(unpack(&packed).is_none());
    }

    #[test]
    fn rejects_empty_input() {
        assert!(unpack(&[]).is_none());
    }

    #[test]
    fn views_are_subslices_of_input() {
        let a = vec![1.0, 2.0];
        let b: Vec<f32> = vec![];
        let c = vec![3.0, 4.0, 5.0];
        let packed = pack(&[&a, &b, &c]);
        let views = unpack_views(&packed).unwrap();
        assert_eq!(views, vec![&a[..], &b[..], &c[..]]);
        // views alias the packed buffer, not fresh allocations
        let base = packed.as_ptr() as usize;
        let end = base + packed.len() * std::mem::size_of::<f32>();
        for v in &views {
            if !v.is_empty() {
                let p = v.as_ptr() as usize;
                assert!(p >= base && p < end, "view escapes the packed buffer");
            }
        }
    }

    #[test]
    fn pack_buffer_reuses_allocation() {
        let mut buf = PackBuffer::new();
        let parts: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32; 32]).collect();
        let first = buf.pack(&parts).to_vec();
        assert_eq!(unpack(&first).unwrap(), parts);
        let cap = buf.capacity();
        for _ in 0..10 {
            let packed = buf.pack(&parts);
            assert_eq!(packed, first.as_slice());
        }
        assert_eq!(buf.capacity(), cap, "steady-state packing must not reallocate");
    }

    #[test]
    fn uniform_parse_matches_views_on_uniform_payloads() {
        let rows = vec![vec![1.0f32, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let packed = pack_vecs(&rows);
        let (n, w, start) = unpack_uniform(&packed).unwrap();
        assert_eq!((n, w), (3, 2));
        assert_eq!(&packed[start..], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let view = unpack_batch_view(&packed).unwrap();
        assert_eq!(view.row(2), &[5.0, 6.0]);
        // empty list and zero-width rows
        assert_eq!(unpack_uniform(&pack(&[])).unwrap(), (0, 0, 1));
        let zw = pack(&[&[][..], &[][..]]);
        assert_eq!(unpack_uniform(&zw).unwrap(), (2, 0, 3));
    }

    #[test]
    fn row_ends_parse_matches_views() {
        let parts = vec![vec![1.0f32, 2.0], vec![], vec![3.0, 4.0, 5.0]];
        let packed = pack_vecs(&parts);
        let (ends, start) = unpack_row_ends(&packed).unwrap();
        assert_eq!(ends, vec![2, 2, 5]);
        assert_eq!(&packed[start..start + 2], &[1.0, 2.0]);
        assert_eq!(&packed[start + 2..start + 5], &[3.0, 4.0, 5.0]);
        // empty list
        assert_eq!(unpack_row_ends(&pack(&[])).unwrap(), (vec![], 1));
        // same rejection set as the view parse
        assert!(unpack_row_ends(&packed[..packed.len() - 1]).is_none());
        let mut garbage = packed.clone();
        garbage.push(9.0);
        assert!(unpack_row_ends(&garbage).is_none());
        assert!(unpack_row_ends(&[]).is_none());
    }

    #[test]
    fn uniform_parse_rejects_ragged_and_malformed() {
        let ragged = pack(&[&[1.0, 2.0][..], &[3.0][..]]);
        assert!(unpack_views(&ragged).is_some(), "ragged is valid for views");
        assert!(unpack_uniform(&ragged).is_none(), "but not for the flat parse");
        let uniform = pack(&[&[1.0, 2.0][..], &[3.0, 4.0][..]]);
        assert!(unpack_uniform(&uniform[..uniform.len() - 1]).is_none());
        let mut garbage = uniform.clone();
        garbage.push(9.0);
        assert!(unpack_uniform(&garbage).is_none());
        assert!(unpack_uniform(&[]).is_none());
    }

    #[test]
    fn flat_encoders_write_identical_wire_bytes() {
        let rows = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        let nested = pack_vecs(&rows);
        let batch = crate::data::batch::Batch::from_rows(&rows).unwrap();
        let mut flat = Vec::new();
        pack_batch_into(&batch.view(), &mut flat);
        assert_eq!(flat, nested);
        // ragged block matches pack over its rows too
        let ragged = vec![vec![1.0f32, 2.0], vec![3.0]];
        let rb = RowBlock::from_rows(&ragged);
        let mut out = Vec::new();
        pack_rows_into_buf(&rb, &mut out);
        assert_eq!(out, pack_vecs(&ragged));
        // PackBuffer twins agree with the free functions
        let mut pb = PackBuffer::new();
        assert_eq!(pb.pack_batch(&batch.view()), nested.as_slice());
        assert_eq!(pb.pack_row_block(&rb), pack_vecs(&ragged).as_slice());
    }

    #[test]
    fn datapoints_roundtrip() {
        let pts = vec![
            (vec![1.0, 2.0], vec![0.5]),
            (vec![3.0], vec![0.25, 0.75]),
        ];
        let packed = pack_datapoints(&pts);
        assert_eq!(unpack_datapoints(&packed).unwrap(), pts);
        let views = unpack_datapoint_views(&packed).unwrap();
        assert_eq!(views.len(), 2);
        assert_eq!(views[0], (&pts[0].0[..], &pts[0].1[..]));
        assert_eq!(views[1], (&pts[1].0[..], &pts[1].1[..]));
    }

    #[test]
    fn train_block_encode_matches_pack_datapoints_bytes() {
        let pts = vec![
            (vec![1.0f32, 2.0], vec![0.5f32]),
            (vec![3.0], vec![0.25, 0.75]),
            (vec![], vec![9.0]),
        ];
        let nested = pack_datapoints(&pts);
        let block = DatapointBlock::from_pairs(&pts);
        let mut flat = Vec::new();
        encode_train_block_into(&block, &mut flat);
        assert_eq!(flat, nested, "flat encoder must write identical wire bytes");
        let mut pb = PackBuffer::new();
        assert_eq!(pb.pack_train_block(&block), nested.as_slice());
        // empty flush
        let empty = DatapointBlock::new();
        let mut out = Vec::new();
        encode_train_block_into(&empty, &mut out);
        assert_eq!(out, pack_datapoints(&[]));
    }

    #[test]
    fn decode_train_block_views_roundtrip_and_rejections() {
        let pts = vec![
            (vec![1.0f32, 2.0], vec![0.5f32]),
            (vec![3.0], vec![0.25, 0.75]),
        ];
        let packed = pack_datapoints(&pts);
        let view = decode_train_block_views(&packed).unwrap();
        assert_eq!(view.len(), 2);
        assert_eq!(view.to_nested(), pts);
        // pairs alias the packed buffer, not fresh allocations
        let base = packed.as_ptr() as usize;
        let end = base + packed.len() * std::mem::size_of::<f32>();
        let p = view.input(0).as_ptr() as usize;
        assert!(p >= base && p < end, "view escapes the packed buffer");
        // odd part count, truncation, trailing garbage, empty input
        let odd = pack(&[&[1.0], &[2.0], &[3.0]]);
        assert!(decode_train_block_views(&odd).is_none());
        assert!(decode_train_block_views(&packed[..packed.len() - 1]).is_none());
        let mut garbage = packed.clone();
        garbage.push(7.0);
        assert!(decode_train_block_views(&garbage).is_none());
        assert!(decode_train_block_views(&[]).is_none());
        // empty list decodes to an empty view
        assert_eq!(decode_train_block_views(&pack_datapoints(&[])).unwrap().len(), 0);
    }

    #[test]
    fn datapoints_odd_parts_rejected() {
        let packed = pack(&[&[1.0], &[2.0], &[3.0]]); // 3 parts: not pairs
        assert!(unpack_datapoints(&packed).is_none());
        assert!(unpack_datapoint_views(&packed).is_none());
    }

    #[test]
    fn large_payload_roundtrip() {
        let big: Vec<f32> = (0..100_000).map(|i| i as f32).collect();
        let packed = pack(&[&big]);
        let got = unpack(&packed).unwrap();
        assert_eq!(got[0], big);
    }
}
