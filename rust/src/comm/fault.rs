//! Deterministic fault injection for the in-process bus.
//!
//! A [`FaultPlan`] is a declarative list of fault actions targeting
//! specific ranks — kill rank *k* after its *N*th send or receive or at an
//! injected run time, drop or delay the first *c* messages matching a
//! `(dst, src, tag)` triple — compiled per rank into a [`FaultState`] that
//! the [`crate::comm::bus::Endpoint`] consults on every send and arrival.
//! Because the triggers count *protocol events* (sends, arrivals) rather
//! than wall-clock samples, a chaos run under a given plan is exactly as
//! reproducible as the clean run it perturbs: the same plan kills the same
//! rank at the same point in its message stream every time.
//!
//! Kills are delivered as panics carrying a [`FaultKill`] payload, so the
//! workflow supervisor ([`crate::coordinator::workflow`]) can distinguish
//! an injected kill from a genuine host bug while treating both as a dead
//! rank. A process-wide panic hook installed on first kill suppresses the
//! default stderr backtrace for `FaultKill` panics only — injected chaos is
//! expected, real panics still print.
//!
//! The empty plan compiles to `None` everywhere: endpoints carry no fault
//! state, take no extra branches beyond one `Option` check, and allocate
//! nothing — clean runs are bit-identical with or without the fault plane.

use std::cell::Cell;
use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// Panic payload carried by an injected kill: the rank that was killed.
/// The workflow supervisor downcasts panic payloads to this type to tell
/// injected faults from genuine host bugs in the degraded-run report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultKill {
    pub rank: usize,
}

/// What a message-matching rule does to a matched arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MsgAction {
    /// Discard the message before it reaches the mailbox.
    Drop,
    /// Deliver, but push the simulated arrival time back by this much.
    Delay(Duration),
}

#[derive(Debug, Clone, Copy)]
enum KillWhen {
    AfterSends(u64),
    AfterRecvs(u64),
    At(Duration),
}

#[derive(Debug, Clone)]
struct KillRule {
    rank: usize,
    when: KillWhen,
}

#[derive(Debug, Clone)]
struct MsgRule {
    /// Receiving rank the rule applies to.
    rank: usize,
    src: usize,
    tag: u32,
    action: MsgAction,
    count: u64,
}

/// A reproducible plan of fault actions. Built fluently, installed on the
/// [`crate::comm::bus::World`] before endpoints are taken (or passed to
/// `Workflow::with_faults`), and compiled per rank at endpoint creation.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    kills: Vec<KillRule>,
    rules: Vec<MsgRule>,
}

impl FaultPlan {
    /// Kill `rank` immediately after its `n`th successful send completes
    /// (the `n`th message is delivered, then the host dies).
    pub fn kill_after_sends(mut self, rank: usize, n: u64) -> Self {
        self.kills.push(KillRule { rank, when: KillWhen::AfterSends(n.max(1)) });
        self
    }

    /// Kill `rank` as its `n`th message arrives (the `n`th message is lost
    /// with the host — it never reaches the mailbox).
    pub fn kill_after_recvs(mut self, rank: usize, n: u64) -> Self {
        self.kills.push(KillRule { rank, when: KillWhen::AfterRecvs(n.max(1)) });
        self
    }

    /// Kill `rank` at the first bus operation at or after `t` past the
    /// plan's installation time.
    pub fn kill_at(mut self, rank: usize, t: Duration) -> Self {
        self.kills.push(KillRule { rank, when: KillWhen::At(t) });
        self
    }

    /// Drop the first `count` messages from `src` with `tag` arriving at
    /// `rank` (silent wire loss).
    pub fn drop_msgs(mut self, rank: usize, src: usize, tag: u32, count: u64) -> Self {
        self.rules.push(MsgRule { rank, src, tag, action: MsgAction::Drop, count });
        self
    }

    /// Delay the first `count` messages from `src` with `tag` arriving at
    /// `rank` by `extra` on top of the world latency.
    pub fn delay_msgs(
        mut self,
        rank: usize,
        src: usize,
        tag: u32,
        extra: Duration,
        count: u64,
    ) -> Self {
        self.rules.push(MsgRule { rank, src, tag, action: MsgAction::Delay(extra), count });
        self
    }

    /// A plan with no actions — the bit-identical no-op.
    pub fn is_empty(&self) -> bool {
        self.kills.is_empty() && self.rules.is_empty()
    }

    /// Compile the per-rank fault state. `None` when no action targets
    /// `rank` — the endpoint then carries no fault machinery at all.
    /// `t0` anchors [`FaultPlan::kill_at`] deadlines.
    pub(crate) fn compile(&self, rank: usize, t0: Instant) -> Option<Box<FaultState>> {
        let mut state = FaultState {
            rank,
            sends: Cell::new(0),
            kill_after_sends: None,
            recvs: Cell::new(0),
            kill_after_recvs: None,
            kill_at: None,
            rules: Vec::new(),
        };
        let mut any = false;
        for k in self.kills.iter().filter(|k| k.rank == rank) {
            any = true;
            match k.when {
                // multiple kill rules for one rank: earliest trigger wins
                KillWhen::AfterSends(n) => {
                    state.kill_after_sends =
                        Some(state.kill_after_sends.map_or(n, |p: u64| p.min(n)));
                }
                KillWhen::AfterRecvs(n) => {
                    state.kill_after_recvs =
                        Some(state.kill_after_recvs.map_or(n, |p: u64| p.min(n)));
                }
                KillWhen::At(d) => {
                    let at = t0 + d;
                    state.kill_at = Some(state.kill_at.map_or(at, |p: Instant| p.min(at)));
                }
            }
        }
        for r in self.rules.iter().filter(|r| r.rank == rank) {
            any = true;
            state.rules.push(CompiledRule {
                src: r.src,
                tag: r.tag,
                action: r.action,
                remaining: Cell::new(r.count),
            });
        }
        any.then(|| Box::new(state))
    }
}

#[derive(Debug)]
struct CompiledRule {
    src: usize,
    tag: u32,
    action: MsgAction,
    remaining: Cell<u64>,
}

/// What the endpoint should do with an arrived message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum ArrivalAction {
    Deliver,
    Drop,
    Delay(Duration),
}

/// Per-rank compiled fault state, consulted by the owning endpoint on
/// every send and arrival. Counters are `Cell`s because sends take
/// `&self`; the state lives inside one endpoint on one thread.
#[derive(Debug)]
pub(crate) struct FaultState {
    rank: usize,
    sends: Cell<u64>,
    kill_after_sends: Option<u64>,
    recvs: Cell<u64>,
    kill_after_recvs: Option<u64>,
    kill_at: Option<Instant>,
    rules: Vec<CompiledRule>,
}

impl FaultState {
    /// Fire a pending time-triggered kill. Called from both the send and
    /// receive paths so an idle polling host still dies on schedule.
    pub(crate) fn check_time(&self, now: Instant) {
        if let Some(t) = self.kill_at {
            if now >= t {
                kill(self.rank);
            }
        }
    }

    /// Count one completed send; panics with [`FaultKill`] once the
    /// configured send count is reached (the message was delivered first).
    pub(crate) fn on_send(&self) {
        let n = self.sends.get() + 1;
        self.sends.set(n);
        if let Some(k) = self.kill_after_sends {
            if n >= k {
                kill(self.rank);
            }
        }
    }

    /// Classify one arriving message. Panics with [`FaultKill`] on the
    /// configured arrival (that message dies with the host); otherwise the
    /// first live matching rule consumes one count and acts.
    pub(crate) fn on_arrival(&self, src: usize, tag: u32) -> ArrivalAction {
        let n = self.recvs.get() + 1;
        self.recvs.set(n);
        if let Some(k) = self.kill_after_recvs {
            if n >= k {
                kill(self.rank);
            }
        }
        for r in &self.rules {
            if r.src == src && r.tag == tag && r.remaining.get() > 0 {
                r.remaining.set(r.remaining.get() - 1);
                return match r.action {
                    MsgAction::Drop => ArrivalAction::Drop,
                    MsgAction::Delay(d) => ArrivalAction::Delay(d),
                };
            }
        }
        ArrivalAction::Deliver
    }
}

/// Panic with a [`FaultKill`] payload, first making sure the process-wide
/// hook that silences injected-kill backtraces is installed. Real panics
/// keep the previous hook's behavior.
fn kill(rank: usize) -> ! {
    static HOOK: OnceLock<()> = OnceLock::new();
    HOOK.get_or_init(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<FaultKill>().is_none() {
                prev(info);
            }
        }));
    });
    std::panic::panic_any(FaultKill { rank });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    fn kill_payload(r: std::thread::Result<()>) -> FaultKill {
        *r.unwrap_err().downcast_ref::<FaultKill>().expect("FaultKill payload")
    }

    #[test]
    fn empty_plan_compiles_to_none_everywhere() {
        let plan = FaultPlan::default();
        assert!(plan.is_empty());
        let t0 = Instant::now();
        for rank in 0..8 {
            assert!(plan.compile(rank, t0).is_none());
        }
    }

    #[test]
    fn compile_targets_only_named_ranks() {
        let plan = FaultPlan::default()
            .kill_after_sends(2, 3)
            .drop_msgs(4, 0, 7, 1);
        assert!(!plan.is_empty());
        let t0 = Instant::now();
        assert!(plan.compile(0, t0).is_none());
        assert!(plan.compile(2, t0).is_some());
        assert!(plan.compile(4, t0).is_some());
    }

    #[test]
    fn kill_after_sends_fires_on_the_nth_send() {
        let plan = FaultPlan::default().kill_after_sends(1, 2);
        let st = plan.compile(1, Instant::now()).unwrap();
        st.on_send(); // 1st: survives
        let r = catch_unwind(AssertUnwindSafe(|| st.on_send()));
        assert_eq!(kill_payload(r), FaultKill { rank: 1 });
    }

    #[test]
    fn kill_after_recvs_fires_on_the_nth_arrival() {
        let plan = FaultPlan::default().kill_after_recvs(3, 2);
        let st = plan.compile(3, Instant::now()).unwrap();
        assert_eq!(st.on_arrival(0, 9), ArrivalAction::Deliver);
        let r = catch_unwind(AssertUnwindSafe(|| {
            st.on_arrival(0, 9);
        }));
        assert_eq!(kill_payload(r), FaultKill { rank: 3 });
    }

    #[test]
    fn kill_at_fires_once_the_deadline_passes() {
        let t0 = Instant::now();
        let plan = FaultPlan::default().kill_at(5, Duration::from_millis(10));
        let st = plan.compile(5, t0).unwrap();
        st.check_time(t0); // before the deadline: survives
        st.check_time(t0 + Duration::from_millis(9));
        let r = catch_unwind(AssertUnwindSafe(|| {
            st.check_time(t0 + Duration::from_millis(10));
        }));
        assert_eq!(kill_payload(r), FaultKill { rank: 5 });
    }

    #[test]
    fn earliest_kill_rule_wins_per_rank() {
        let plan = FaultPlan::default().kill_after_sends(1, 5).kill_after_sends(1, 2);
        let st = plan.compile(1, Instant::now()).unwrap();
        st.on_send();
        let r = catch_unwind(AssertUnwindSafe(|| st.on_send()));
        assert_eq!(kill_payload(r), FaultKill { rank: 1 });
    }

    #[test]
    fn drop_rule_consumes_its_count_then_delivers() {
        let plan = FaultPlan::default().drop_msgs(2, 1, 7, 2);
        let st = plan.compile(2, Instant::now()).unwrap();
        assert_eq!(st.on_arrival(1, 7), ArrivalAction::Drop);
        assert_eq!(st.on_arrival(1, 7), ArrivalAction::Drop);
        assert_eq!(st.on_arrival(1, 7), ArrivalAction::Deliver, "count exhausted");
        // non-matching (src, tag) never drops
        assert_eq!(st.on_arrival(0, 7), ArrivalAction::Deliver);
        assert_eq!(st.on_arrival(1, 8), ArrivalAction::Deliver);
    }

    #[test]
    fn delay_rule_adds_extra_latency() {
        let extra = Duration::from_millis(25);
        let plan = FaultPlan::default().delay_msgs(2, 0, 9, extra, 1);
        let st = plan.compile(2, Instant::now()).unwrap();
        assert_eq!(st.on_arrival(0, 9), ArrivalAction::Delay(extra));
        assert_eq!(st.on_arrival(0, 9), ArrivalAction::Deliver);
    }
}
