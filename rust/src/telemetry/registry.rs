//! Process-wide live metrics registry — the publish side of the
//! observability plane.
//!
//! [`KernelTelemetry`](super::KernelTelemetry) is post-mortem: it is only
//! visible after a host joins. The [`MetricsRegistry`] is the *live* view:
//! Manager, Exchange, the dispatch core, the oracle plane, and the host
//! supervisors publish into one process-wide set of relaxed atomics while
//! the run is in flight, and the metrics server
//! ([`super::server`]) renders a consistent-enough snapshot on every
//! scrape without ever touching a lock on the publish path.
//!
//! Publish-path cost model, in order:
//! - registry **disabled** (the default — no `--metrics-addr`, no bench
//!   opt-in): one relaxed load + one predictable branch, zero stores,
//!   zero allocations. `BENCH_obs.json` gates this with the counting
//!   allocator.
//! - registry **enabled**: one relaxed `fetch_add`/`store` per event,
//!   still zero allocations — all storage is fixed-size arrays of
//!   atomics sized at init.
//!
//! Naming scheme (Prometheus exposition): every series is prefixed
//! `pal_`; monotonic counters end in `_total`; instantaneous values are
//! bare gauges (`pal_oracle_queue_depth`); latency distributions are
//! log₂-bucketed histograms in milliseconds (`pal_oracle_rtt_ms`);
//! per-endpoint series carry `{rank="…",kind="…"}` labels. The same
//! names (sans prefix) appear in the `/status` JSON snapshot.

use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::comm::bus::WorldStats;
use crate::json::{obj, Value};

/// Endpoint/rank slots the registry pre-allocates. Ranks at or above this
/// simply aren't tracked per-endpoint (global counters still see them).
pub const MAX_RANKS: usize = 128;

/// Monotonic global counters. One atomic each, published with
/// [`MetricsRegistry::inc`]/[`MetricsRegistry::add`] at the same sites
/// that bump the matching [`KernelTelemetry`](super::KernelTelemetry)
/// counter — so the live view and the post-mortem report agree by
/// construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum Counter {
    /// Labeled samples ingested by the Manager.
    Labels = 0,
    /// Inputs dispatched to oracles.
    Dispatched,
    /// Oracle micro-batches dispatched.
    OracleBatches,
    /// Prediction micro-batches dispatched by the Exchange.
    PredBatches,
    /// Candidate samples selected for oracle labeling.
    SelectedForOracle,
    /// Exchange main-loop iterations.
    AlIterations,
    /// Retrain rounds observed by the Manager.
    RetrainRounds,
    /// Weight syncs broadcast by trainers.
    WeightSyncs,
    /// Oracles evicted by the Manager (fault plane).
    OracleEvictions,
    /// Prediction shards evicted by the Exchange (fault plane).
    ShardEvictions,
    /// Oracle inputs requeued after an eviction.
    RequeuedInputs,
    /// Prediction items requeued after a shard eviction.
    RequeuedItems,
    /// Dispatched inputs lost with a dead host.
    LostInputs,
    /// Dispatches that dead-lettered on send.
    DeadLetterDispatches,
    /// Undecodable/unknown-sender frames.
    BadFrames,
    /// TAG_RANK_DOWN notices processed by coordinators.
    RankDownNotices,
    /// Host panics caught by the supervisor (incl. injected faults).
    HostFailures,
}

const N_COUNTERS: usize = Counter::HostFailures as usize + 1;

impl Counter {
    const ALL: [Counter; N_COUNTERS] = [
        Counter::Labels,
        Counter::Dispatched,
        Counter::OracleBatches,
        Counter::PredBatches,
        Counter::SelectedForOracle,
        Counter::AlIterations,
        Counter::RetrainRounds,
        Counter::WeightSyncs,
        Counter::OracleEvictions,
        Counter::ShardEvictions,
        Counter::RequeuedInputs,
        Counter::RequeuedItems,
        Counter::LostInputs,
        Counter::DeadLetterDispatches,
        Counter::BadFrames,
        Counter::RankDownNotices,
        Counter::HostFailures,
    ];

    /// Prometheus series name (also the `/status` JSON key sans `pal_`).
    pub fn name(self) -> &'static str {
        match self {
            Counter::Labels => "pal_labels_total",
            Counter::Dispatched => "pal_dispatched_inputs_total",
            Counter::OracleBatches => "pal_oracle_batches_total",
            Counter::PredBatches => "pal_pred_batches_total",
            Counter::SelectedForOracle => "pal_selected_for_oracle_total",
            Counter::AlIterations => "pal_al_iterations_total",
            Counter::RetrainRounds => "pal_retrain_rounds_total",
            Counter::WeightSyncs => "pal_weight_syncs_total",
            Counter::OracleEvictions => "pal_oracle_evictions_total",
            Counter::ShardEvictions => "pal_shard_evictions_total",
            Counter::RequeuedInputs => "pal_requeued_inputs_total",
            Counter::RequeuedItems => "pal_requeued_items_total",
            Counter::LostInputs => "pal_lost_inputs_total",
            Counter::DeadLetterDispatches => "pal_dead_letter_dispatches_total",
            Counter::BadFrames => "pal_bad_frames_total",
            Counter::RankDownNotices => "pal_rank_down_notices_total",
            Counter::HostFailures => "pal_host_failures_total",
        }
    }

    fn json_key(self) -> &'static str {
        // strip "pal_" — the JSON snapshot nests under explicit sections
        &self.name()[4..]
    }
}

/// Instantaneous gauges, overwritten each coordinator pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum Gauge {
    /// Inputs buffered at the Manager awaiting oracle dispatch.
    OracleQueueDepth = 0,
    /// Labeled pairs buffered at the Manager awaiting a train flush.
    TrainBufferDepth,
    /// Generator items queued at the Exchange awaiting a shard.
    PredQueueDepth,
    /// Oracle batches currently in flight.
    OracleInFlight,
    /// Oracle *items* currently in flight.
    OracleInFlightItems,
    /// Prediction batches currently in flight.
    PredInFlight,
}

const N_GAUGES: usize = Gauge::PredInFlight as usize + 1;

impl Gauge {
    const ALL: [Gauge; N_GAUGES] = [
        Gauge::OracleQueueDepth,
        Gauge::TrainBufferDepth,
        Gauge::PredQueueDepth,
        Gauge::OracleInFlight,
        Gauge::OracleInFlightItems,
        Gauge::PredInFlight,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Gauge::OracleQueueDepth => "pal_oracle_queue_depth",
            Gauge::TrainBufferDepth => "pal_train_buffer_depth",
            Gauge::PredQueueDepth => "pal_pred_queue_depth",
            Gauge::OracleInFlight => "pal_oracle_in_flight_batches",
            Gauge::OracleInFlightItems => "pal_oracle_in_flight_items",
            Gauge::PredInFlight => "pal_pred_in_flight_batches",
        }
    }

    fn json_key(self) -> &'static str {
        &self.name()[4..]
    }
}

/// What kind of kernel a rank hosts (for `/status` and endpoint labels).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u64)]
pub enum RankKind {
    Unknown = 0,
    Manager,
    Exchange,
    Prediction,
    Training,
    Generator,
    Oracle,
}

impl RankKind {
    fn from_u64(v: u64) -> RankKind {
        match v {
            1 => RankKind::Manager,
            2 => RankKind::Exchange,
            3 => RankKind::Prediction,
            4 => RankKind::Training,
            5 => RankKind::Generator,
            6 => RankKind::Oracle,
            _ => RankKind::Unknown,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RankKind::Unknown => "unknown",
            RankKind::Manager => "manager",
            RankKind::Exchange => "exchange",
            RankKind::Prediction => "prediction",
            RankKind::Training => "training",
            RankKind::Generator => "generator",
            RankKind::Oracle => "oracle",
        }
    }

    /// Map a host thread's kernel label (as used by `supervised`) back to
    /// a kind; unknown labels stay `Unknown`.
    pub fn from_kernel(kernel: &str) -> RankKind {
        match kernel {
            "manager" => RankKind::Manager,
            "exchange" => RankKind::Exchange,
            "prediction" => RankKind::Prediction,
            "training" => RankKind::Training,
            "generator" => RankKind::Generator,
            "oracle" => RankKind::Oracle,
            _ => RankKind::Unknown,
        }
    }
}

/// Lifecycle state of a rank's host thread.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u64)]
pub enum RankState {
    Absent = 0,
    Running,
    Done,
    Failed,
}

impl RankState {
    fn from_u64(v: u64) -> RankState {
        match v {
            1 => RankState::Running,
            2 => RankState::Done,
            3 => RankState::Failed,
            _ => RankState::Absent,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RankState::Absent => "absent",
            RankState::Running => "running",
            RankState::Done => "done",
            RankState::Failed => "failed",
        }
    }
}

/// Per-rank slot: kernel kind + lifecycle + (for dispatch endpoints)
/// outstanding work and smoothed latency. All fields relaxed atomics;
/// `ewma_ms` carries `f64::to_bits`.
#[derive(Default)]
struct RankSlot {
    kind: AtomicU64,
    state: AtomicU64,
    outstanding: AtomicU64,
    outstanding_items: AtomicU64,
    completed_batches: AtomicU64,
    ewma_ms_bits: AtomicU64,
    dead: AtomicU64,
}

impl RankSlot {
    fn reset(&self) {
        self.kind.store(0, Ordering::Relaxed);
        self.state.store(0, Ordering::Relaxed);
        self.outstanding.store(0, Ordering::Relaxed);
        self.outstanding_items.store(0, Ordering::Relaxed);
        self.completed_batches.store(0, Ordering::Relaxed);
        self.ewma_ms_bits.store(0, Ordering::Relaxed);
        self.dead.store(0, Ordering::Relaxed);
    }
}

/// Log₂-bucketed latency histogram in milliseconds: `le` bounds
/// 1, 2, 4, …, 2^15 ms plus +Inf. Fixed shape → publish is one
/// `fetch_add` into a bucket plus count/sum, zero allocations.
pub struct AtomicHistogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

const HIST_BUCKETS: usize = 17; // le=1..=32768 ms (16) + +Inf

impl Default for AtomicHistogram {
    fn default() -> Self {
        AtomicHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }
}

impl AtomicHistogram {
    fn bucket_bound_ms(i: usize) -> u64 {
        1u64 << i
    }

    fn observe(&self, d: Duration) {
        let ms = d.as_millis() as u64;
        // index of the first power-of-two bound >= ms (+Inf past 2^15)
        let idx = if ms <= 1 {
            0
        } else {
            let b = 64 - (ms - 1).leading_zeros() as usize;
            b.min(HIST_BUCKETS - 1)
        };
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(d.as_micros() as u64, Ordering::Relaxed);
    }

    fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_us.store(0, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    fn sum_ms(&self) -> f64 {
        self.sum_us.load(Ordering::Relaxed) as f64 / 1e3
    }

    fn mean_ms(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_ms() / n as f64
        }
    }

    /// Approximate nearest-rank percentile: the upper bound of the bucket
    /// holding the q-th observation (+Inf reports the largest finite bound).
    fn percentile_ms(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_bound_ms(i.min(HIST_BUCKETS - 2)) as f64;
            }
        }
        Self::bucket_bound_ms(HIST_BUCKETS - 2) as f64
    }

    /// Cumulative Prometheus buckets: `(le_label, cumulative_count)`.
    fn cumulative(&self) -> Vec<(String, u64)> {
        let mut out = Vec::with_capacity(HIST_BUCKETS);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            let le = if i == HIST_BUCKETS - 1 {
                "+Inf".to_string()
            } else {
                format!("{}", Self::bucket_bound_ms(i))
            };
            out.push((le, cum));
        }
        out
    }
}

/// The process-wide live metrics registry. One instance per process
/// (see [`registry()`]); a [`Workflow`](crate::coordinator::Workflow)
/// run resets it at start when observability is configured.
pub struct MetricsRegistry {
    enabled: AtomicBool,
    counters: [AtomicU64; N_COUNTERS],
    gauges: [AtomicU64; N_GAUGES],
    ranks: [RankSlot; MAX_RANKS],
    /// Oracle-leg round-trip (dispatch → labels ingested at the Manager).
    oracle_rtt: AtomicHistogram,
    /// Prediction-leg round-trip (dispatch → batch completed at the Exchange).
    pred_rtt: AtomicHistogram,
    /// Run start, for scrape-time rates (labels/sec). Scrape-path only.
    start: Mutex<Option<Instant>>,
    /// Live transport stats of the current run's `World`. Scrape-path only.
    world: Mutex<Option<Arc<WorldStats>>>,
    /// Address the metrics server actually bound (port 0 resolves here).
    bound_addr: Mutex<Option<SocketAddr>>,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        MetricsRegistry {
            enabled: AtomicBool::new(false),
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            gauges: std::array::from_fn(|_| AtomicU64::new(0)),
            ranks: std::array::from_fn(|_| RankSlot::default()),
            oracle_rtt: AtomicHistogram::default(),
            pred_rtt: AtomicHistogram::default(),
            start: Mutex::new(None),
            world: Mutex::new(None),
            bound_addr: Mutex::new(None),
        }
    }
}

static REGISTRY: OnceLock<MetricsRegistry> = OnceLock::new();

/// The process-wide registry (created on first touch, disabled until a
/// run or bench enables it).
pub fn registry() -> &'static MetricsRegistry {
    REGISTRY.get_or_init(MetricsRegistry::default)
}

impl MetricsRegistry {
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Turn publication on/off. Off is the hot-path no-op state.
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Zero every counter/gauge/slot/histogram and (re)arm the run clock.
    /// Called by `Workflow::run_on` before any kernel thread spawns.
    pub fn reset_for_run(&self, world: Option<Arc<WorldStats>>) {
        for c in &self.counters {
            c.store(0, Ordering::Relaxed);
        }
        for g in &self.gauges {
            g.store(0, Ordering::Relaxed);
        }
        for r in &self.ranks {
            r.reset();
        }
        self.oracle_rtt.reset();
        self.pred_rtt.reset();
        *self.start.lock().unwrap() = Some(Instant::now());
        *self.world.lock().unwrap() = world;
    }

    // ---- publish path (hot; enabled-gated, relaxed, allocation-free) ----

    #[inline]
    pub fn inc(&self, c: Counter) {
        self.add(c, 1);
    }

    #[inline]
    pub fn add(&self, c: Counter, n: u64) {
        if !self.enabled() {
            return;
        }
        self.counters[c as usize].fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn gauge_set(&self, g: Gauge, v: u64) {
        if !self.enabled() {
            return;
        }
        self.gauges[g as usize].store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn observe_oracle_rtt(&self, d: Duration) {
        if !self.enabled() {
            return;
        }
        self.oracle_rtt.observe(d);
    }

    #[inline]
    pub fn observe_pred_rtt(&self, d: Duration) {
        if !self.enabled() {
            return;
        }
        self.pred_rtt.observe(d);
    }

    /// Per-endpoint outstanding work, published by the dispatch core on
    /// every dispatch/complete transition.
    #[inline]
    pub fn endpoint_outstanding(&self, rank: usize, batches: u64, items: u64) {
        if !self.enabled() || rank >= MAX_RANKS {
            return;
        }
        let s = &self.ranks[rank];
        s.outstanding.store(batches, Ordering::Relaxed);
        s.outstanding_items.store(items, Ordering::Relaxed);
    }

    /// Per-endpoint smoothed latency (EWMA ms), published on completion.
    #[inline]
    pub fn endpoint_ewma_ms(&self, rank: usize, ms: f64) {
        if !self.enabled() || rank >= MAX_RANKS {
            return;
        }
        let s = &self.ranks[rank];
        s.ewma_ms_bits.store(ms.to_bits(), Ordering::Relaxed);
        s.completed_batches.fetch_add(1, Ordering::Relaxed);
    }

    /// Mark an endpoint dead/alive (fault-plane eviction + readmission).
    #[inline]
    pub fn endpoint_dead(&self, rank: usize, dead: bool) {
        if !self.enabled() || rank >= MAX_RANKS {
            return;
        }
        self.ranks[rank].dead.store(dead as u64, Ordering::Relaxed);
    }

    /// Register a rank's kernel kind (idempotent; survives state changes).
    pub fn set_rank_kind(&self, rank: usize, kind: RankKind) {
        if !self.enabled() || rank >= MAX_RANKS {
            return;
        }
        self.ranks[rank].kind.store(kind as u64, Ordering::Relaxed);
    }

    /// Publish a rank's lifecycle transition (supervisor call sites).
    pub fn set_rank_state(&self, rank: usize, state: RankState) {
        if !self.enabled() || rank >= MAX_RANKS {
            return;
        }
        self.ranks[rank].state.store(state as u64, Ordering::Relaxed);
    }

    // ---- scrape path (server-only; locks allowed) ----

    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c as usize].load(Ordering::Relaxed)
    }

    pub fn gauge(&self, g: Gauge) -> u64 {
        self.gauges[g as usize].load(Ordering::Relaxed)
    }

    pub fn oracle_rtt_count(&self) -> u64 {
        self.oracle_rtt.count()
    }

    pub fn set_bound_addr(&self, addr: Option<SocketAddr>) {
        *self.bound_addr.lock().unwrap() = addr;
    }

    /// The metrics server's actual bound address (tests bind port 0).
    pub fn bound_addr(&self) -> Option<SocketAddr> {
        *self.bound_addr.lock().unwrap()
    }

    fn elapsed_s(&self) -> f64 {
        self.start.lock().unwrap().map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0)
    }

    fn labels_per_sec(&self) -> f64 {
        let el = self.elapsed_s();
        if el <= 0.0 {
            0.0
        } else {
            self.counter(Counter::Labels) as f64 / el
        }
    }

    fn ranks_with_state(&self, want: RankState) -> Vec<usize> {
        (0..MAX_RANKS)
            .filter(|&r| {
                RankState::from_u64(self.ranks[r].state.load(Ordering::Relaxed)) == want
            })
            .collect()
    }

    /// Render the full Prometheus text exposition (format 0.0.4).
    pub fn render_prometheus(&self) -> String {
        let mut out = String::with_capacity(4096);
        for c in Counter::ALL {
            out.push_str(&format!("# TYPE {} counter\n", c.name()));
            out.push_str(&format!("{} {}\n", c.name(), self.counter(c)));
        }
        for g in Gauge::ALL {
            out.push_str(&format!("# TYPE {} gauge\n", g.name()));
            out.push_str(&format!("{} {}\n", g.name(), self.gauge(g)));
        }
        out.push_str("# TYPE pal_labels_per_sec gauge\n");
        out.push_str(&format!("pal_labels_per_sec {:.3}\n", self.labels_per_sec()));
        out.push_str("# TYPE pal_run_elapsed_seconds gauge\n");
        out.push_str(&format!("pal_run_elapsed_seconds {:.3}\n", self.elapsed_s()));
        if let Some(w) = self.world.lock().unwrap().as_ref() {
            for (name, v) in [
                ("pal_world_messages_total", w.messages()),
                ("pal_world_payload_bytes_total", w.payload_bytes()),
                ("pal_world_payload_clones_total", w.payload_clones()),
                ("pal_world_bytes_copied_total", w.bytes_copied()),
                ("pal_world_dead_letters_total", w.dead_letters()),
            ] {
                out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
            }
        }
        for (hist, name) in
            [(&self.oracle_rtt, "pal_oracle_rtt_ms"), (&self.pred_rtt, "pal_pred_rtt_ms")]
        {
            out.push_str(&format!("# TYPE {name} histogram\n"));
            for (le, cum) in hist.cumulative() {
                out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
            }
            out.push_str(&format!("{name}_sum {:.3}\n", hist.sum_ms()));
            out.push_str(&format!("{name}_count {}\n", hist.count()));
        }
        out.push_str("# TYPE pal_endpoint_outstanding_batches gauge\n");
        out.push_str("# TYPE pal_endpoint_ewma_ms gauge\n");
        out.push_str("# TYPE pal_endpoint_dead gauge\n");
        for (rank, s) in self.ranks.iter().enumerate() {
            let kind = RankKind::from_u64(s.kind.load(Ordering::Relaxed));
            let completed = s.completed_batches.load(Ordering::Relaxed);
            let outstanding = s.outstanding.load(Ordering::Relaxed);
            if completed == 0 && outstanding == 0 && kind == RankKind::Unknown {
                continue;
            }
            let labels = format!("{{rank=\"{rank}\",kind=\"{}\"}}", kind.name());
            out.push_str(&format!("pal_endpoint_outstanding_batches{labels} {outstanding}\n"));
            let ewma = f64::from_bits(s.ewma_ms_bits.load(Ordering::Relaxed));
            out.push_str(&format!("pal_endpoint_ewma_ms{labels} {ewma:.3}\n"));
            out.push_str(&format!(
                "pal_endpoint_dead{labels} {}\n",
                s.dead.load(Ordering::Relaxed)
            ));
        }
        out
    }

    /// Render the `/status` JSON snapshot: run progress, queues, live
    /// fault counters (consistent with the final
    /// [`FaultReport`](super::FaultReport) fields by shared call sites),
    /// per-rank kernel state, per-endpoint dispatch state, and transport
    /// stats.
    pub fn snapshot_json(&self) -> Value {
        let run = obj(vec![
            ("elapsed_s", Value::Num(self.elapsed_s())),
            ("labels", Value::Num(self.counter(Counter::Labels) as f64)),
            ("labels_per_sec", Value::Num(self.labels_per_sec())),
            ("al_iterations", Value::Num(self.counter(Counter::AlIterations) as f64)),
            ("retrain_rounds", Value::Num(self.counter(Counter::RetrainRounds) as f64)),
            ("weight_syncs", Value::Num(self.counter(Counter::WeightSyncs) as f64)),
        ]);
        let counters = Value::Object(
            Counter::ALL
                .iter()
                .map(|&c| (c.json_key().to_string(), Value::Num(self.counter(c) as f64)))
                .collect(),
        );
        let queues = Value::Object(
            Gauge::ALL
                .iter()
                .map(|&g| (g.json_key().to_string(), Value::Num(self.gauge(g) as f64)))
                .collect(),
        );
        let world = match self.world.lock().unwrap().as_ref() {
            Some(w) => obj(vec![
                ("messages", Value::Num(w.messages() as f64)),
                ("payload_bytes", Value::Num(w.payload_bytes() as f64)),
                ("payload_clones", Value::Num(w.payload_clones() as f64)),
                ("bytes_copied", Value::Num(w.bytes_copied() as f64)),
                ("dead_letters", Value::Num(w.dead_letters() as f64)),
            ]),
            None => Value::Null,
        };
        let dead_letters = self
            .world
            .lock()
            .unwrap()
            .as_ref()
            .map(|w| w.dead_letters())
            .unwrap_or(0);
        let faults = obj(vec![
            (
                "failed_ranks",
                Value::Array(
                    self.ranks_with_state(RankState::Failed)
                        .into_iter()
                        .map(|r| Value::Num(r as f64))
                        .collect(),
                ),
            ),
            ("oracle_evictions", Value::Num(self.counter(Counter::OracleEvictions) as f64)),
            ("shard_evictions", Value::Num(self.counter(Counter::ShardEvictions) as f64)),
            ("requeued_inputs", Value::Num(self.counter(Counter::RequeuedInputs) as f64)),
            ("requeued_items", Value::Num(self.counter(Counter::RequeuedItems) as f64)),
            ("lost_inputs", Value::Num(self.counter(Counter::LostInputs) as f64)),
            ("bad_frames", Value::Num(self.counter(Counter::BadFrames) as f64)),
            ("dead_letters", Value::Num(dead_letters as f64)),
        ]);
        let mut ranks = Vec::new();
        for (rank, s) in self.ranks.iter().enumerate() {
            let state = RankState::from_u64(s.state.load(Ordering::Relaxed));
            let kind = RankKind::from_u64(s.kind.load(Ordering::Relaxed));
            if state == RankState::Absent && kind == RankKind::Unknown {
                continue;
            }
            let mut fields = vec![
                ("rank", Value::Num(rank as f64)),
                ("kernel", Value::Str(kind.name().to_string())),
                ("state", Value::Str(state.name().to_string())),
            ];
            let outstanding = s.outstanding.load(Ordering::Relaxed);
            let completed = s.completed_batches.load(Ordering::Relaxed);
            if outstanding > 0 || completed > 0 {
                fields.push(("outstanding_batches", Value::Num(outstanding as f64)));
                fields.push((
                    "outstanding_items",
                    Value::Num(s.outstanding_items.load(Ordering::Relaxed) as f64),
                ));
                fields.push(("completed_batches", Value::Num(completed as f64)));
                fields.push((
                    "ewma_ms",
                    Value::Num(f64::from_bits(s.ewma_ms_bits.load(Ordering::Relaxed))),
                ));
                fields.push((
                    "dead",
                    Value::Bool(s.dead.load(Ordering::Relaxed) != 0),
                ));
            }
            ranks.push(obj(fields));
        }
        let latency = obj(vec![
            (
                "oracle_rtt",
                obj(vec![
                    ("count", Value::Num(self.oracle_rtt.count() as f64)),
                    ("mean_ms", Value::Num(self.oracle_rtt.mean_ms())),
                    ("p95_ms", Value::Num(self.oracle_rtt.percentile_ms(0.95))),
                ]),
            ),
            (
                "pred_rtt",
                obj(vec![
                    ("count", Value::Num(self.pred_rtt.count() as f64)),
                    ("mean_ms", Value::Num(self.pred_rtt.mean_ms())),
                    ("p95_ms", Value::Num(self.pred_rtt.percentile_ms(0.95))),
                ]),
            ),
        ]);
        obj(vec![
            ("run", run),
            ("counters", counters),
            ("queues", queues),
            ("latency", latency),
            ("world", world),
            ("faults", faults),
            ("ranks", Value::Array(ranks)),
        ])
    }
}

/// Serializes lib tests (across telemetry submodules) that mutate the
/// process-wide registry.
#[cfg(test)]
pub(crate) static TEST_LOCK: Mutex<()> = Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    struct Enabled;
    impl Enabled {
        fn new() -> Self {
            registry().reset_for_run(None);
            registry().set_enabled(true);
            Enabled
        }
    }
    impl Drop for Enabled {
        fn drop(&mut self) {
            registry().set_enabled(false);
        }
    }

    #[test]
    fn disabled_registry_ignores_publishes() {
        let _g = TEST_LOCK.lock().unwrap();
        let r = registry();
        r.reset_for_run(None);
        r.set_enabled(false);
        r.inc(Counter::Labels);
        r.gauge_set(Gauge::OracleQueueDepth, 9);
        r.observe_oracle_rtt(Duration::from_millis(5));
        r.endpoint_ewma_ms(3, 5.0);
        assert_eq!(r.counter(Counter::Labels), 0);
        assert_eq!(r.gauge(Gauge::OracleQueueDepth), 0);
        assert_eq!(r.oracle_rtt_count(), 0);
    }

    #[test]
    fn enabled_registry_accumulates_and_renders() {
        let _g = TEST_LOCK.lock().unwrap();
        let _e = Enabled::new();
        let r = registry();
        r.add(Counter::Labels, 12);
        r.inc(Counter::OracleEvictions);
        r.gauge_set(Gauge::OracleQueueDepth, 4);
        r.observe_oracle_rtt(Duration::from_millis(3));
        r.observe_oracle_rtt(Duration::from_millis(70));
        r.set_rank_kind(5, RankKind::Oracle);
        r.set_rank_state(5, RankState::Running);
        r.endpoint_outstanding(5, 2, 16);
        r.endpoint_ewma_ms(5, 6.25);
        assert_eq!(r.counter(Counter::Labels), 12);
        let prom = r.render_prometheus();
        assert!(prom.contains("pal_labels_total 12"));
        assert!(prom.contains("pal_oracle_evictions_total 1"));
        assert!(prom.contains("pal_oracle_queue_depth 4"));
        assert!(prom.contains("pal_oracle_rtt_ms_count 2"));
        assert!(prom.contains("pal_oracle_rtt_ms_bucket{le=\"+Inf\"} 2"));
        assert!(prom.contains("pal_endpoint_outstanding_batches{rank=\"5\",kind=\"oracle\"} 2"));
        let snap = r.snapshot_json();
        assert_eq!(snap.path("run.labels").as_f64(), Some(12.0));
        assert_eq!(snap.path("faults.oracle_evictions").as_f64(), Some(1.0));
        assert_eq!(snap.path("latency.oracle_rtt.count").as_f64(), Some(2.0));
        let ranks = snap.get("ranks").as_array().unwrap();
        assert!(ranks.iter().any(|v| {
            v.get("rank").as_f64() == Some(5.0)
                && v.get("kernel").as_str() == Some("oracle")
                && v.get("state").as_str() == Some("running")
        }));
    }

    #[test]
    fn histogram_percentile_is_bucket_bound() {
        let h = AtomicHistogram::default();
        for _ in 0..95 {
            h.observe(Duration::from_millis(2));
        }
        for _ in 0..5 {
            h.observe(Duration::from_millis(300));
        }
        // p50 lands in the le=2 bucket, p99 in le=512
        assert_eq!(h.percentile_ms(0.50), 2.0);
        assert_eq!(h.percentile_ms(0.99), 512.0);
    }

    #[test]
    fn failed_rank_listed_in_status() {
        let _g = TEST_LOCK.lock().unwrap();
        let _e = Enabled::new();
        let r = registry();
        r.set_rank_kind(7, RankKind::Prediction);
        r.set_rank_state(7, RankState::Failed);
        r.inc(Counter::HostFailures);
        let snap = r.snapshot_json();
        let failed = snap.path("faults.failed_ranks").as_array().unwrap();
        assert_eq!(failed, &[Value::Num(7.0)]);
    }
}
