//! Per-kernel timing and counters; aggregated into the run report.
//!
//! The paper reports per-phase latencies (§3.1: 51.5 ms committee forward,
//! 4.27 ms communication + propagation). Each kernel host owns a
//! [`KernelTelemetry`], times its phases with [`KernelTelemetry::time`],
//! and returns it on join; [`RunReport`] aggregates across ranks.
//!
//! Post-mortem telemetry is complemented by the live observability plane:
//! [`registry`] is the process-wide atomic [`registry::MetricsRegistry`]
//! the coordinators publish into while a run is in flight, [`server`]
//! serves it over HTTP (`/metrics`, `/status`, `/healthz`), and [`trace`]
//! records per-rank phase spans drained into Chrome trace-event JSON.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::json::{obj, Value};

pub mod registry;
pub mod server;
pub mod trace;

/// Accumulating timer: count + total + max.
#[derive(Debug, Default, Clone, Copy)]
pub struct Timer {
    pub count: u64,
    pub total: Duration,
    pub max: Duration,
}

impl Timer {
    pub fn record(&mut self, d: Duration) {
        self.count += 1;
        self.total += d;
        if d > self.max {
            self.max = d;
        }
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            Duration::ZERO
        } else {
            self.total / self.count as u32
        }
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean().as_secs_f64() * 1e3
    }
}

/// Bounded ring of recent duration samples with percentile queries.
///
/// [`Timer`] keeps count/total/max only, which is enough for means but not
/// for tail-aware decisions (the dispatch core scales the Manager's shutdown
/// drain bound with observed p95 oracle latency). This window keeps the last
/// `cap` samples and answers percentiles by nearest-rank over a reusable
/// sort scratch — O(n log n) per query on a small bounded n, but zero
/// steady-state allocations now that the metrics server queries it on
/// every scrape rather than once per drain.
#[derive(Debug)]
pub struct LatencyWindow {
    samples: Vec<Duration>,
    next: usize,
    cap: usize,
    /// Reusable percentile sort buffer; interior mutability keeps the
    /// `&self` query signature for the read-mostly call sites.
    scratch: RefCell<Vec<Duration>>,
}

impl Default for LatencyWindow {
    fn default() -> Self {
        LatencyWindow::new(256)
    }
}

impl Clone for LatencyWindow {
    fn clone(&self) -> Self {
        // the scratch is a cache, not state — fresh clones start empty
        LatencyWindow {
            samples: self.samples.clone(),
            next: self.next,
            cap: self.cap,
            scratch: RefCell::new(Vec::new()),
        }
    }
}

impl LatencyWindow {
    pub fn new(cap: usize) -> Self {
        LatencyWindow {
            samples: Vec::new(),
            next: 0,
            cap: cap.max(1),
            scratch: RefCell::new(Vec::new()),
        }
    }

    pub fn record(&mut self, d: Duration) {
        if self.samples.len() < self.cap {
            self.samples.push(d);
        } else {
            self.samples[self.next] = d;
            self.next = (self.next + 1) % self.cap;
        }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Nearest-rank percentile (`q` in [0, 1]) over the retained samples.
    /// Sorts into the reusable scratch buffer: the first query allocates
    /// it, every later query (one per `/metrics` scrape) reuses it.
    pub fn percentile(&self, q: f64) -> Option<Duration> {
        if self.samples.is_empty() {
            return None;
        }
        let mut sorted = self.scratch.borrow_mut();
        sorted.clear();
        sorted.extend_from_slice(&self.samples);
        sorted.sort_unstable();
        let rank = ((q.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize)
            .saturating_sub(1)
            .min(sorted.len() - 1);
        Some(sorted[rank])
    }

    pub fn p95(&self) -> Option<Duration> {
        self.percentile(0.95)
    }
}

/// One kernel instance's telemetry.
#[derive(Debug, Default, Clone)]
pub struct KernelTelemetry {
    pub kernel: String,
    pub rank: usize,
    pub counters: BTreeMap<String, u64>,
    pub timers: BTreeMap<String, Timer>,
}

impl KernelTelemetry {
    pub fn new(kernel: &str, rank: usize) -> Self {
        KernelTelemetry { kernel: kernel.into(), rank, ..Default::default() }
    }

    pub fn bump(&mut self, counter: &str) {
        self.add(counter, 1);
    }

    pub fn add(&mut self, counter: &str, n: u64) {
        *self.counters.entry(counter.to_string()).or_default() += n;
    }

    pub fn record(&mut self, timer: &str, d: Duration) {
        self.timers.entry(timer.to_string()).or_default().record(d);
    }

    /// Time a closure under `timer`.
    pub fn time<T>(&mut self, timer: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(timer, t0.elapsed());
        out
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn timer(&self, name: &str) -> Timer {
        self.timers.get(name).copied().unwrap_or_default()
    }

    pub fn to_json(&self) -> Value {
        let counters = Value::Object(
            self.counters.iter().map(|(k, v)| (k.clone(), Value::Num(*v as f64))).collect(),
        );
        let timers = Value::Object(
            self.timers
                .iter()
                .map(|(k, t)| {
                    (
                        k.clone(),
                        obj(vec![
                            ("count", Value::Num(t.count as f64)),
                            ("mean_ms", Value::Num(t.mean_ms())),
                            ("total_ms", Value::Num(t.total.as_secs_f64() * 1e3)),
                            ("max_ms", Value::Num(t.max.as_secs_f64() * 1e3)),
                        ]),
                    )
                })
                .collect(),
        );
        obj(vec![
            ("kernel", Value::Str(self.kernel.clone())),
            ("rank", Value::Num(self.rank as f64)),
            ("counters", counters),
            ("timers", timers),
        ])
    }
}

/// Fault-plane summary of one run: which hosts died and what the
/// coordinators did about it. An all-zero report (see
/// [`FaultReport::is_clean`]) is the healthy steady state; anything else
/// means the run completed *degraded* and the numbers say how.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct FaultReport {
    /// Ranks whose hosts panicked or were fault-killed (sorted).
    pub failed_ranks: Vec<usize>,
    /// Oracles permanently or temporarily evicted by the Manager.
    pub oracle_evictions: u64,
    /// Prediction shards evicted by the Exchange.
    pub shard_evictions: u64,
    /// Oracle inputs requeued after an eviction (relabeled elsewhere).
    pub requeued_inputs: u64,
    /// Prediction items requeued after a shard eviction.
    pub requeued_items: u64,
    /// Dispatched inputs lost with a dead host (not retained/requeueable).
    pub lost_inputs: u64,
    /// Undecodable frames observed across all kernels.
    pub bad_frames: u64,
    /// Sends that found the destination endpoint already dropped.
    pub dead_letters: u64,
}

impl FaultReport {
    /// No host died and nothing was evicted, requeued, lost, or malformed.
    /// `dead_letters` is deliberately excluded: the shutdown fan-out sets the
    /// stop flag before waking every rank, so a host that polls the flag can
    /// drop its endpoint a beat before the wake-up send lands. Those benign
    /// races are still reported in the count, but they do not make a run
    /// degraded — every *harmful* dead letter also surfaces as an eviction
    /// or a failed rank.
    pub fn is_clean(&self) -> bool {
        self.failed_ranks.is_empty()
            && self.oracle_evictions == 0
            && self.shard_evictions == 0
            && self.requeued_inputs == 0
            && self.requeued_items == 0
            && self.lost_inputs == 0
            && self.bad_frames == 0
    }

    pub fn to_json(&self) -> Value {
        obj(vec![
            (
                "failed_ranks",
                Value::Array(self.failed_ranks.iter().map(|&r| Value::Num(r as f64)).collect()),
            ),
            ("oracle_evictions", Value::Num(self.oracle_evictions as f64)),
            ("shard_evictions", Value::Num(self.shard_evictions as f64)),
            ("requeued_inputs", Value::Num(self.requeued_inputs as f64)),
            ("requeued_items", Value::Num(self.requeued_items as f64)),
            ("lost_inputs", Value::Num(self.lost_inputs as f64)),
            ("bad_frames", Value::Num(self.bad_frames as f64)),
            ("dead_letters", Value::Num(self.dead_letters as f64)),
        ])
    }
}

/// Aggregated result of one workflow run.
#[derive(Debug, Default, Clone)]
pub struct RunReport {
    /// Exchange loop iterations completed.
    pub al_iterations: u64,
    /// Samples labeled by the oracle kernel.
    pub oracle_labels: u64,
    /// Retraining rounds completed across trainers.
    pub retrain_rounds: u64,
    /// Final (most recent) training losses per trainer.
    pub final_losses: Vec<f32>,
    /// Total wall time.
    pub wall: Duration,
    /// Per-rank telemetry.
    pub kernels: Vec<KernelTelemetry>,
    /// comm stats: total messages and *logical* payload bytes (counted per
    /// destination, so broadcasts scale with fan-out).
    pub messages: u64,
    pub payload_bytes: u64,
    /// Payload buffers the transport physically materialized (deep copies).
    /// Shared-payload broadcasts and relay re-sends contribute zero.
    pub payload_clones: u64,
    /// Bytes physically copied by the transport (the copy volume behind
    /// `payload_clones`; compare against `payload_bytes` to see sharing).
    pub bytes_copied: u64,
    /// Fault-plane summary: failed ranks, evictions, requeues, dead
    /// letters. Clean runs carry an all-zero report.
    pub faults: FaultReport,
}

impl RunReport {
    /// All telemetry of one kernel type.
    pub fn kernel(&self, name: &str) -> Vec<&KernelTelemetry> {
        self.kernels.iter().filter(|k| k.kernel == name).collect()
    }

    /// Mean of a timer across ranks of a kernel (ms).
    pub fn mean_timer_ms(&self, kernel: &str, timer: &str) -> f64 {
        let ks = self.kernel(kernel);
        let (mut total, mut count) = (Duration::ZERO, 0u64);
        for k in ks {
            let t = k.timer(timer);
            total += t.total;
            count += t.count;
        }
        if count == 0 {
            0.0
        } else {
            total.as_secs_f64() * 1e3 / count as f64
        }
    }

    /// Sum of a counter across ranks of a kernel.
    pub fn sum_counter(&self, kernel: &str, counter: &str) -> u64 {
        self.kernel(kernel).iter().map(|k| k.counter(counter)).sum()
    }

    /// Sum of a counter across every kernel of every rank.
    pub fn sum_counter_all(&self, counter: &str) -> u64 {
        self.kernels.iter().map(|k| k.counter(counter)).sum()
    }

    /// Aggregated `UploadCache` effectiveness across every engine-backed
    /// kernel (prediction replicas + trainers): cache hits skip the
    /// host→device staging copy entirely, `bytes_reused` is the staging
    /// volume those hits avoided.
    pub fn upload_cache_json(&self) -> Value {
        obj(vec![
            ("hits", Value::Num(self.sum_counter_all("upload_cache_hits") as f64)),
            ("misses", Value::Num(self.sum_counter_all("upload_cache_misses") as f64)),
            (
                "bytes_uploaded",
                Value::Num(self.sum_counter_all("upload_cache_bytes_uploaded") as f64),
            ),
            ("bytes_reused", Value::Num(self.sum_counter_all("upload_cache_bytes_reused") as f64)),
        ])
    }

    pub fn to_json(&self) -> Value {
        obj(vec![
            ("al_iterations", Value::Num(self.al_iterations as f64)),
            ("oracle_labels", Value::Num(self.oracle_labels as f64)),
            ("retrain_rounds", Value::Num(self.retrain_rounds as f64)),
            ("wall_s", Value::Num(self.wall.as_secs_f64())),
            ("messages", Value::Num(self.messages as f64)),
            ("payload_bytes", Value::Num(self.payload_bytes as f64)),
            ("payload_clones", Value::Num(self.payload_clones as f64)),
            ("bytes_copied", Value::Num(self.bytes_copied as f64)),
            (
                "final_losses",
                Value::Array(self.final_losses.iter().map(|l| Value::Num(*l as f64)).collect()),
            ),
            ("faults", self.faults.to_json()),
            ("upload_cache", self.upload_cache_json()),
            ("kernels", Value::Array(self.kernels.iter().map(|k| k.to_json()).collect())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_accumulates() {
        let mut t = Timer::default();
        t.record(Duration::from_millis(10));
        t.record(Duration::from_millis(30));
        assert_eq!(t.count, 2);
        assert_eq!(t.max, Duration::from_millis(30));
        assert!((t.mean_ms() - 20.0).abs() < 1.0);
    }

    #[test]
    fn latency_window_percentiles() {
        let mut w = LatencyWindow::new(100);
        assert_eq!(w.p95(), None);
        for ms in 1..=100u64 {
            w.record(Duration::from_millis(ms));
        }
        assert_eq!(w.percentile(0.5), Some(Duration::from_millis(50)));
        assert_eq!(w.p95(), Some(Duration::from_millis(95)));
        assert_eq!(w.percentile(1.0), Some(Duration::from_millis(100)));
        assert_eq!(w.percentile(0.0), Some(Duration::from_millis(1)));
    }

    #[test]
    fn latency_window_percentile_scratch_is_reused() {
        let mut w = LatencyWindow::new(64);
        for ms in [5u64, 1, 9, 3] {
            w.record(Duration::from_millis(ms));
        }
        // repeated queries (the per-scrape pattern) stay consistent and
        // interleave with records without disturbing the ring
        for _ in 0..3 {
            assert_eq!(w.percentile(1.0), Some(Duration::from_millis(9)));
            assert_eq!(w.percentile(0.0), Some(Duration::from_millis(1)));
        }
        w.record(Duration::from_millis(20));
        assert_eq!(w.p95(), Some(Duration::from_millis(20)));
        // clones answer queries independently of the source's scratch
        let c = w.clone();
        assert_eq!(c.percentile(0.5), w.percentile(0.5));
    }

    #[test]
    fn latency_window_evicts_oldest_beyond_cap() {
        let mut w = LatencyWindow::new(4);
        for ms in [1u64, 2, 3, 4, 100, 100] {
            w.record(Duration::from_millis(ms));
        }
        assert_eq!(w.len(), 4);
        // 1 and 2 were overwritten; the max of the retained set is 100.
        assert_eq!(w.percentile(1.0), Some(Duration::from_millis(100)));
        assert_eq!(w.percentile(0.0), Some(Duration::from_millis(3)));
    }

    #[test]
    fn telemetry_counters_and_timers() {
        let mut k = KernelTelemetry::new("prediction", 2);
        k.bump("predictions");
        k.add("predictions", 4);
        let out = k.time("fwd", || 7);
        assert_eq!(out, 7);
        assert_eq!(k.counter("predictions"), 5);
        assert_eq!(k.timer("fwd").count, 1);
        let j = k.to_json();
        assert_eq!(j.get("kernel").as_str(), Some("prediction"));
    }

    #[test]
    fn report_aggregates_across_ranks() {
        let mut r = RunReport::default();
        for rank in 0..3 {
            let mut k = KernelTelemetry::new("prediction", rank);
            k.record("fwd", Duration::from_millis(10));
            k.bump("n");
            r.kernels.push(k);
        }
        assert_eq!(r.sum_counter("prediction", "n"), 3);
        assert!((r.mean_timer_ms("prediction", "fwd") - 10.0).abs() < 2.0);
        assert_eq!(r.mean_timer_ms("oracle", "calc"), 0.0);
    }

    #[test]
    fn report_aggregates_upload_cache_counters() {
        let mut r = RunReport::default();
        let mut p = KernelTelemetry::new("prediction", 2);
        p.add("upload_cache_hits", 7);
        p.add("upload_cache_bytes_reused", 640);
        let mut t = KernelTelemetry::new("training", 5);
        t.add("upload_cache_hits", 3);
        t.add("upload_cache_misses", 1);
        r.kernels.push(p);
        r.kernels.push(t);
        let j = r.to_json();
        let up = j.get("upload_cache");
        assert_eq!(up.get("hits").as_f64(), Some(10.0));
        assert_eq!(up.get("misses").as_f64(), Some(1.0));
        assert_eq!(up.get("bytes_reused").as_f64(), Some(640.0));
    }
}
