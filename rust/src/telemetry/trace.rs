//! Bounded per-rank span recorder drained into Chrome trace-event JSON.
//!
//! Every kernel host records phase spans (one [`TraceEvent`] per
//! completed phase) into its *own* lane — a per-rank `Mutex<Vec<_>>`
//! that only that rank's thread locks while recording, so recording is
//! uncontended and the cost is one lock + one `Vec::push` into
//! pre-reserved capacity. Lanes are bounded ([`LANE_CAP`] events per
//! rank); overflow is dropped and counted, never reallocated past the
//! cap.
//!
//! Span taxonomy (names are stable; the observability e2e asserts span
//! counts against `RunReport` counters):
//! - `predict` — one committee forward on a prediction rank
//!   (== prediction `batches`)
//! - `oracle_calc` — one labeling call on an oracle rank
//!   (== oracle `batches`)
//! - `retrain` — one training round on a trainer rank
//!   (== training `rounds`)
//! - `weight_sync` — one weight broadcast from a trainer
//!   (== training `weight_syncs`)
//! - `oracle_batch` — Manager-side oracle-leg lifecycle,
//!   dispatch → labels ingested
//! - `pred_batch` — Exchange-side prediction-leg lifecycle,
//!   dispatch → completion ingested
//! - `rank_down` (instant) — a host panicked or was fault-killed
//! - `evict` (instant) — a coordinator evicted a dead endpoint
//!
//! The drained file is a plain Chrome trace-event array (`ph: "X"` for
//! spans, `ph: "i"` for instants, `tid` = rank) loadable in Perfetto or
//! `chrome://tracing`.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Per-rank lanes pre-allocated by the sink (ranks past this share lane 0's
/// fate: they are simply not recorded).
pub const MAX_RANKS: usize = super::registry::MAX_RANKS;

/// Events retained per rank before dropping (bounds memory on long runs).
pub const LANE_CAP: usize = 65_536;

/// One recorded phase span or instant event.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Stable span name from the module-level taxonomy.
    pub name: &'static str,
    /// Wall-clock start of the span.
    pub t0: Instant,
    /// Span duration (zero for instant events).
    pub dur: Duration,
    /// Recording rank (becomes `tid`).
    pub rank: usize,
    /// Span-specific id (batch id, round index, …); `u64::MAX` = none.
    pub id: u64,
    /// Item count carried by the span (0 = not applicable).
    pub items: u64,
}

/// The process-wide trace sink (see [`sink()`]).
pub struct TraceSink {
    enabled: AtomicBool,
    lanes: [Mutex<Vec<TraceEvent>>; MAX_RANKS],
    dropped: AtomicU64,
}

static SINK: OnceLock<TraceSink> = OnceLock::new();

/// The process-wide sink (created on first touch, disabled by default).
pub fn sink() -> &'static TraceSink {
    SINK.get_or_init(|| TraceSink {
        enabled: AtomicBool::new(false),
        lanes: std::array::from_fn(|_| Mutex::new(Vec::new())),
        dropped: AtomicU64::new(0),
    })
}

impl TraceSink {
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Clear all lanes and start recording. Called by `Workflow::run_on`
    /// when `trace_out` is configured.
    pub fn begin(&self) {
        for lane in &self.lanes {
            lane.lock().unwrap().clear();
        }
        self.dropped.store(0, Ordering::Relaxed);
        self.enabled.store(true, Ordering::Relaxed);
    }

    /// Stop recording (lanes keep their events until the next `begin`).
    pub fn end(&self) {
        self.enabled.store(false, Ordering::Relaxed);
    }

    /// Record a completed span. No-op while disabled.
    #[inline]
    pub fn span(&self, rank: usize, name: &'static str, t0: Instant, id: u64, items: u64) {
        if !self.enabled() || rank >= MAX_RANKS {
            return;
        }
        let dur = t0.elapsed();
        self.push(TraceEvent { name, t0, dur, rank, id, items });
    }

    /// Record an instant event (zero duration). No-op while disabled.
    #[inline]
    pub fn instant(&self, rank: usize, name: &'static str, id: u64) {
        if !self.enabled() || rank >= MAX_RANKS {
            return;
        }
        self.push(TraceEvent { name, t0: Instant::now(), dur: Duration::ZERO, rank, id, items: 0 });
    }

    fn push(&self, ev: TraceEvent) {
        let mut lane = self.lanes[ev.rank].lock().unwrap();
        if lane.len() >= LANE_CAP {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        if lane.capacity() == 0 {
            lane.reserve(1024);
        }
        lane.push(ev);
    }

    /// Events dropped to the per-lane cap since the last `begin`.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Count of recorded spans with `name` across all lanes.
    pub fn count(&self, name: &str) -> u64 {
        self.lanes
            .iter()
            .map(|l| l.lock().unwrap().iter().filter(|e| e.name == name).count() as u64)
            .sum()
    }

    /// Drain every lane into a Chrome trace-event JSON array string.
    /// Timestamps are microseconds relative to the earliest recorded
    /// event, so the trace always starts at ts=0.
    pub fn drain_chrome_json(&self) -> String {
        let mut events: Vec<TraceEvent> = Vec::new();
        for lane in &self.lanes {
            events.append(&mut lane.lock().unwrap());
        }
        let origin = events.iter().map(|e| e.t0).min();
        let mut out = String::with_capacity(events.len() * 96 + 2);
        out.push('[');
        for (i, e) in events.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let ts = origin.map(|o| e.t0.duration_since(o).as_micros() as u64).unwrap_or(0);
            let ph = if e.dur.is_zero() { "i" } else { "X" };
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"ph\":\"{}\",\"ts\":{},\"dur\":{},\"pid\":0,\"tid\":{}",
                e.name,
                ph,
                ts,
                e.dur.as_micros() as u64,
                e.rank
            ));
            if ph == "i" {
                // chrome requires a scope on instant events
                out.push_str(",\"s\":\"t\"");
            }
            out.push_str(&format!(",\"args\":{{\"id\":{},\"items\":{}}}}}", e.id, e.items));
        }
        out.push(']');
        out
    }

    /// Drain to a file at `path` (the `--trace-out` target).
    pub fn drain_to_file(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.drain_chrome_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    static TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_sink_records_nothing() {
        let _g = TEST_LOCK.lock().unwrap();
        let s = sink();
        s.begin();
        s.end();
        s.span(1, "predict", Instant::now(), 0, 4);
        s.instant(1, "rank_down", 1);
        assert_eq!(s.count("predict"), 0);
        assert_eq!(s.drain_chrome_json(), "[]");
    }

    #[test]
    fn spans_drain_as_chrome_trace() {
        let _g = TEST_LOCK.lock().unwrap();
        let s = sink();
        s.begin();
        let t0 = Instant::now();
        std::thread::sleep(Duration::from_millis(2));
        s.span(3, "oracle_calc", t0, 7, 8);
        s.instant(5, "rank_down", 5);
        s.end();
        assert_eq!(s.count("oracle_calc"), 1);
        let json = s.drain_chrome_json();
        let v = crate::json::parse(&json).expect("valid json");
        let arr = v.as_array().unwrap();
        assert_eq!(arr.len(), 2);
        let span = arr
            .iter()
            .find(|e| e.get("name").as_str() == Some("oracle_calc"))
            .expect("span present");
        assert_eq!(span.get("ph").as_str(), Some("X"));
        assert_eq!(span.get("tid").as_f64(), Some(3.0));
        assert!(span.get("dur").as_f64().unwrap() >= 1_000.0);
        assert_eq!(span.path("args.items").as_f64(), Some(8.0));
        let inst = arr
            .iter()
            .find(|e| e.get("name").as_str() == Some("rank_down"))
            .expect("instant present");
        assert_eq!(inst.get("ph").as_str(), Some("i"));
        // drained — lanes are now empty
        assert_eq!(s.drain_chrome_json(), "[]");
    }

    #[test]
    fn lane_cap_drops_and_counts() {
        let _g = TEST_LOCK.lock().unwrap();
        let s = sink();
        s.begin();
        let t0 = Instant::now();
        for i in 0..(LANE_CAP + 10) {
            s.span(2, "predict", t0, i as u64, 1);
        }
        s.end();
        assert_eq!(s.count("predict"), LANE_CAP as u64);
        assert_eq!(s.dropped(), 10);
        // clean up the big lane so other tests start fresh
        s.begin();
        s.end();
    }
}
