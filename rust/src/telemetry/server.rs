//! Minimal HTTP metrics/admin surface over `std::net` (no new deps, same
//! stack as [`crate::comm::transport::tcp`]).
//!
//! Routes:
//! - `GET /metrics` — Prometheus text exposition (format 0.0.4) of the
//!   live [`MetricsRegistry`](super::registry::MetricsRegistry)
//! - `GET /status` — JSON snapshot: run progress, queue depths, live
//!   fault counters, per-rank kernel state, per-endpoint dispatch state
//! - `GET /healthz` — liveness probe, always `200 ok`
//!
//! [`MetricsServer::start`] binds (port 0 allowed — the resolved address
//! is published via
//! [`registry().bound_addr()`](super::registry::MetricsRegistry::bound_addr)
//! and returned by [`MetricsServer::addr`]), then serves scrapes from one
//! accept-loop thread. Requests are handled inline — scrapes are small,
//! rare, and read-only, so a connection pool would be dead weight. The
//! server never touches the bus or any kernel lock: everything it renders
//! comes from the registry's atomics and the `Arc<WorldStats>` snapshot.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::registry::registry;
use crate::json::to_string;

/// How long the accept loop sleeps between polls while idle.
const ACCEPT_IDLE: Duration = Duration::from_millis(5);

/// Per-connection read/write deadline — a stalled scraper cannot wedge
/// the accept loop for longer than this.
const IO_TIMEOUT: Duration = Duration::from_millis(500);

/// Largest request head we will buffer before answering 400.
const MAX_REQUEST: usize = 8192;

/// Running metrics/admin HTTP server; stop it with [`MetricsServer::stop`]
/// (also invoked on drop).
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9090`, port 0 for ephemeral) and start
    /// the accept loop. Publishes the resolved address to the registry so
    /// in-process scrapers (tests) can find an ephemeral port.
    pub fn start(addr: &str) -> io::Result<MetricsServer> {
        let listener = TcpListener::bind(addr)?;
        let bound = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        registry().set_bound_addr(Some(bound));
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("pal-metrics".into())
            .spawn(move || accept_loop(listener, stop2))
            .expect("spawn metrics server thread");
        Ok(MetricsServer { addr: bound, stop, handle: Some(handle) })
    }

    /// The address actually bound (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Signal the accept loop and join it.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        registry().set_bound_addr(None);
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        if self.handle.is_some() {
            self.shutdown();
        }
    }
}

/// Accept-loop body: nonblocking accept + idle sleep, so the stop flag is
/// observed within one [`ACCEPT_IDLE`] even with no traffic.
fn accept_loop(listener: TcpListener, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _)) => {
                // scrape errors (hangups, timeouts) only affect that client
                let _ = handle_conn(stream);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_IDLE);
            }
            Err(_) => std::thread::sleep(ACCEPT_IDLE),
        }
    }
}

/// Read one request head, route it, write one response, close.
fn handle_conn(mut stream: TcpStream) -> io::Result<()> {
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    stream.set_nonblocking(false)?;
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    // read until the blank line ending the request head (we ignore bodies)
    while !buf.windows(4).any(|w| w == b"\r\n\r\n") {
        if buf.len() > MAX_REQUEST {
            return respond(&mut stream, 400, "text/plain", "request too large");
        }
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) => return Err(e),
        }
    }
    let head = String::from_utf8_lossy(&buf);
    let mut parts = head.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    if method != "GET" {
        return respond(&mut stream, 405, "text/plain", "method not allowed");
    }
    // ignore any query string — routes take no parameters
    match path.split('?').next().unwrap_or("") {
        "/metrics" => {
            let body = registry().render_prometheus();
            respond(&mut stream, 200, "text/plain; version=0.0.4; charset=utf-8", &body)
        }
        "/status" => {
            let body = to_string(&registry().snapshot_json());
            respond(&mut stream, 200, "application/json", &body)
        }
        "/healthz" | "/" => respond(&mut stream, 200, "text/plain", "ok\n"),
        _ => respond(&mut stream, 404, "text/plain", "not found"),
    }
}

fn respond(stream: &mut TcpStream, code: u16, content_type: &str, body: &str) -> io::Result<()> {
    let reason = match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        _ => "Error",
    };
    let head = format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Blocking in-process HTTP GET against `addr` — the scrape helper the
/// observability tests (and the CLI's own smoke checks) use so no external
/// HTTP client is needed. Returns `(status_code, body)`.
pub fn http_get(addr: SocketAddr, path: &str) -> io::Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    let req = format!("GET {path} HTTP/1.1\r\nHost: pal\r\nConnection: close\r\n\r\n");
    stream.write_all(req.as_bytes())?;
    let mut raw = String::new();
    stream.read_to_string(&mut raw)?;
    let code = raw
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse::<u16>().ok())
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "malformed http response"))?;
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    Ok((code, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::registry::{Counter, Gauge, TEST_LOCK};

    #[test]
    fn serves_metrics_status_and_healthz() {
        let _g = TEST_LOCK.lock().unwrap();
        registry().reset_for_run(None);
        registry().set_enabled(true);
        registry().add(Counter::Labels, 3);
        registry().gauge_set(Gauge::OracleQueueDepth, 2);
        let server = MetricsServer::start("127.0.0.1:0").expect("bind");
        let addr = server.addr();
        assert_eq!(registry().bound_addr(), Some(addr));

        let (code, body) = http_get(addr, "/healthz").unwrap();
        assert_eq!((code, body.as_str()), (200, "ok\n"));

        let (code, body) = http_get(addr, "/metrics").unwrap();
        assert_eq!(code, 200);
        assert!(body.contains("pal_labels_total 3"));
        assert!(body.contains("pal_oracle_queue_depth 2"));
        assert!(body.contains("# TYPE pal_oracle_rtt_ms histogram"));

        let (code, body) = http_get(addr, "/status").unwrap();
        assert_eq!(code, 200);
        let v = crate::json::parse(&body).expect("valid status json");
        assert_eq!(v.path("run.labels").as_f64(), Some(3.0));
        assert_eq!(v.path("queues.oracle_queue_depth").as_f64(), Some(2.0));

        let (code, _) = http_get(addr, "/nope").unwrap();
        assert_eq!(code, 404);

        server.stop();
        registry().set_enabled(false);
        // the bound address is withdrawn once the server is gone
        assert_eq!(registry().bound_addr(), None);
    }

    #[test]
    fn concurrent_scrapes_all_succeed() {
        let _g = TEST_LOCK.lock().unwrap();
        registry().reset_for_run(None);
        registry().set_enabled(true);
        let server = MetricsServer::start("127.0.0.1:0").expect("bind");
        let addr = server.addr();
        let handles: Vec<_> = (0..8)
            .map(|i| {
                std::thread::spawn(move || {
                    let path = if i % 2 == 0 { "/metrics" } else { "/status" };
                    http_get(addr, path).map(|(code, _)| code)
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap().unwrap(), 200);
        }
        server.stop();
        registry().set_enabled(false);
    }
}
