//! End-to-end validation driver (DESIGN.md experiment "end-to-end"):
//! active-learn an LJ₈ cluster potential through the full PAL stack and log
//! the learning curve — held-out MSE and committee uncertainty vs labels.
//!
//! The run is phased: each phase is a complete PAL workflow bounded by a
//! label budget; committee members checkpoint to `results/end_to_end/` (the
//! paper's `save_progress` persistence) so weights and datasets carry over.
//! Between phases the driver evaluates every member on a fixed
//! oracle-labeled test set and records energy MSE + committee std.
//!
//! ```sh
//! make artifacts && cargo run --release --example end_to_end
//! ```

use std::sync::Arc;
use std::time::Duration;

use pal::config::{AlSetting, StopCriteria};
use pal::coordinator::selection::CommitteeStdUtils;
use pal::coordinator::workflow::Workflow;
use pal::json::{arr_f64, obj, Value};
use pal::kernels::generators::{MdGenerator, MdLayout};
use pal::kernels::models::{HloPotentialModel, TrainOptions};
use pal::kernels::oracles::{LatencyOracle, PesOracle};
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::potential::{LennardJones, Pes};
use pal::rng::Rng;
use pal::runtime::{default_artifacts_dir, Manifest};

const N_ATOMS: usize = 8; // ground1 artifact set
const COMMITTEE: usize = 4;
const PHASES: usize = 6;
const LABELS_PER_PHASE: u64 = 24;
const RESULT_DIR: &str = "results/end_to_end";

fn ckpt_path(replica: usize) -> std::path::PathBuf {
    std::path::Path::new(RESULT_DIR).join(format!("member_{replica}.ckpt.json"))
}

fn input_row(x: &[f32]) -> Vec<f32> {
    let mut row = x.to_vec();
    row.push(0.0); // global
    row.push(1.0); // ground state
    row
}

/// Fixed held-out test set: thermally perturbed LJ₈ geometries + labels.
fn test_set(n: usize) -> Vec<(Vec<f32>, f32)> {
    let pes = LennardJones::cluster(N_ATOMS);
    let mut rng = Rng::new(0xE2E);
    (0..n)
        .map(|_| {
            let mut x = pes.initial_geometry(&mut rng);
            for v in &mut x {
                *v += (rng.normal() * 0.08) as f32;
            }
            let e = pes.energy(&x) as f32;
            (input_row(&x), e)
        })
        .collect()
}

/// Evaluate the checkpointed committee on the test set:
/// (energy MSE of the committee mean, mean committee std).
fn evaluate(test: &[(Vec<f32>, f32)]) -> anyhow::Result<(f64, f64)> {
    let dir = default_artifacts_dir();
    let rows: Vec<Vec<f32>> = test.iter().map(|(x, _)| x.clone()).collect();
    let mut per_member: Vec<Vec<f32>> = Vec::new();
    for replica in 0..COMMITTEE {
        let opts = TrainOptions { checkpoint: Some(ckpt_path(replica)), ..Default::default() };
        let mut model = HloPotentialModel::new(
            Manifest::load(&dir)?,
            "ground1",
            Mode::Predict,
            200 + replica as u32,
            opts,
        )?;
        let preds = model.predict(&rows);
        per_member.push(preds.iter().map(|p| p[0]).collect()); // energy channel
    }
    let m = COMMITTEE as f64;
    let mut mse = 0.0;
    let mut mean_std = 0.0;
    for (i, (_, e_ref)) in test.iter().enumerate() {
        let vals: Vec<f64> = per_member.iter().map(|p| p[i] as f64).collect();
        let mean = vals.iter().sum::<f64>() / m;
        let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / (m - 1.0);
        mse += (mean - *e_ref as f64) * (mean - *e_ref as f64);
        mean_std += var.sqrt();
    }
    Ok((mse / test.len() as f64, mean_std / test.len() as f64))
}

fn run_phase(phase: usize) -> anyhow::Result<pal::telemetry::RunReport> {
    let setting = AlSetting {
        result_dir: RESULT_DIR.into(),
        gene_process: 8,
        pred_process: COMMITTEE,
        ml_process: COMMITTEE,
        orcl_process: 4,
        retrain_size: 8,
        stop: StopCriteria {
            max_iterations: None,
            max_labels: Some(LABELS_PER_PHASE),
            max_wall: Some(Duration::from_secs(120)),
            ..Default::default()
        },
        ..Default::default()
    };
    let layout = MdLayout { n_atoms: N_ATOMS, n_globals: 1, n_states: 1 };
    let generators: Vec<_> = (0..setting.gene_process)
        .map(|i| {
            let seed = (phase * 100 + i) as u64;
            Box::new(move || {
                let pes = LennardJones::cluster(N_ATOMS);
                let mut rng = Rng::new(seed);
                let x0 = pes.initial_geometry(&mut rng);
                Box::new(
                    MdGenerator::new(layout, x0, seed).with_dt(0.01).with_patience(4),
                ) as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();
    let oracles: Vec<_> = (0..setting.orcl_process)
        .map(|i| {
            Box::new(move || {
                Box::new(
                    LatencyOracle::new(
                        PesOracle::fixed(LennardJones::cluster(N_ATOMS), 1),
                        Duration::from_millis(60),
                    )
                    .with_jitter(0.2, i as u64),
                ) as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();
    let dir = default_artifacts_dir();
    let model = Arc::new(move |mode: Mode, replica: usize| {
        let manifest = Manifest::load(&dir).expect("artifacts");
        let opts = TrainOptions {
            epochs_per_round: 24,
            checkpoint: Some(ckpt_path(replica)),
            ..Default::default()
        };
        Box::new(
            HloPotentialModel::new(manifest, "ground1", mode, 200 + replica as u32, opts)
                .expect("lj model"),
        ) as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(CommitteeStdUtils::new(0.3, 6)) as Box<dyn Utils>);
    Workflow::new(setting).run(KernelSet { generators, oracles, model, utils })
}

fn main() -> anyhow::Result<()> {
    std::fs::create_dir_all(RESULT_DIR)?;
    // fresh run: clear stale checkpoints
    for r in 0..COMMITTEE {
        let _ = std::fs::remove_file(ckpt_path(r));
    }
    let test = test_set(64);

    println!("=== PAL end-to-end validation: LJ{N_ATOMS} committee potential ===");
    println!(
        "{PHASES} phases x {LABELS_PER_PHASE} labels; committee of {COMMITTEE}; held-out test set of {}",
        test.len()
    );
    println!();
    println!("{:<8} {:>8} {:>12} {:>14} {:>12}", "phase", "labels", "test MSE", "committee std", "retrains");

    let mut labels_total = 0u64;
    let mut curve_mse = Vec::new();
    let mut curve_std = Vec::new();
    let mut curve_labels = Vec::new();

    // phase 0: untrained committee baseline
    let (mse0, std0) = evaluate(&test)?;
    println!("{:<8} {:>8} {:>12.4} {:>14.4} {:>12}", "init", 0, mse0, std0, 0);
    curve_labels.push(0.0);
    curve_mse.push(mse0);
    curve_std.push(std0);

    for phase in 0..PHASES {
        let report = run_phase(phase)?;
        labels_total += report.oracle_labels;
        let (mse, std) = evaluate(&test)?;
        println!(
            "{:<8} {:>8} {:>12.4} {:>14.4} {:>12}",
            phase, labels_total, mse, std, report.retrain_rounds
        );
        curve_labels.push(labels_total as f64);
        curve_mse.push(mse);
        curve_std.push(std);
    }

    let improved = curve_mse.last().unwrap() < curve_mse.first().unwrap();
    println!();
    println!(
        "learning curve: MSE {:.4} -> {:.4} ({})",
        curve_mse.first().unwrap(),
        curve_mse.last().unwrap(),
        if improved { "improved" } else { "NOT improved" }
    );

    let curve = obj(vec![
        ("labels", arr_f64(&curve_labels)),
        ("test_mse", arr_f64(&curve_mse)),
        ("committee_std", arr_f64(&curve_std)),
        ("improved", Value::Bool(improved)),
    ]);
    let path = format!("{RESULT_DIR}/learning_curve.json");
    std::fs::write(&path, pal::json::to_string(&curve))?;
    println!("curve written to {path}");
    Ok(())
}
