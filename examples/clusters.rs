//! Inorganic-cluster application (paper §3.3, Fig. 3c):
//! MD trajectories over Bi₈-like clusters in several charge states; a
//! Gupta-type many-body potential stands in for DFT (TPSS/dhf-TZVP); the
//! charge state rides along as the model's global feature so one committee
//! covers multiple potential-energy surfaces, as in the paper.
//!
//! ```sh
//! make artifacts && cargo run --release --example clusters
//! ```

use std::sync::Arc;
use std::time::Duration;

use pal::config::{AlSetting, StopCriteria};
use pal::coordinator::selection::CommitteeStdUtils;
use pal::coordinator::workflow::Workflow;
use pal::kernels::generators::{MdGenerator, MdLayout};
use pal::kernels::models::{HloPotentialModel, TrainOptions};
use pal::kernels::oracles::{LatencyOracle, PesOracle};
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::potential::{Gupta, Pes};
use pal::rng::Rng;
use pal::runtime::{default_artifacts_dir, Manifest};

const N_ATOMS: usize = 8; // ground1 artifact set
const CHARGES: [f64; 3] = [-1.0, 0.0, 1.0];

fn main() -> anyhow::Result<()> {
    let setting = AlSetting {
        result_dir: "results/clusters".into(),
        gene_process: 9, // 3 trajectories per charge state
        pred_process: 4,
        ml_process: 4,
        orcl_process: 3,
        retrain_size: 16,
        dynamic_oracle_list: true, // re-score the DFT queue after retrains
        stop: StopCriteria {
            max_iterations: Some(150),
            max_labels: Some(96),
            max_wall: Some(Duration::from_secs(180)),
            ..Default::default()
        },
        ..Default::default()
    };

    let layout = MdLayout { n_atoms: N_ATOMS, n_globals: 1, n_states: 1 };

    let generators: Vec<_> = (0..setting.gene_process)
        .map(|i| {
            let charge = CHARGES[i % CHARGES.len()];
            Box::new(move || {
                let mut rng = Rng::new(900 + i as u64);
                let pes = Gupta::bismuth(N_ATOMS, charge);
                let x0 = pes.initial_geometry(&mut rng);
                Box::new(
                    MdGenerator::new(layout, x0, 900 + i as u64)
                        .with_dt(0.05)
                        .with_patience(4)
                        .with_globals(vec![charge as f32]),
                ) as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();

    // DFT stand-in: charge-aware Gupta labels + heavy simulated latency
    // (the bottleneck kernel in this application, §3.3)
    let oracles: Vec<_> = (0..setting.orcl_process)
        .map(|i| {
            Box::new(move || {
                Box::new(
                    LatencyOracle::new(
                        PesOracle::from_globals(N_ATOMS, 1, |g| {
                            Gupta::bismuth(N_ATOMS, g[0] as f64)
                        }),
                        Duration::from_millis(250),
                    )
                    .with_jitter(0.3, i as u64),
                ) as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();

    let dir = default_artifacts_dir();
    let model = Arc::new(move |mode: Mode, replica: usize| {
        let manifest = Manifest::load(&dir).expect("artifacts");
        let opts = TrainOptions { epochs_per_round: 16, ..Default::default() };
        Box::new(
            HloPotentialModel::new(manifest, "ground1", mode, 80 + replica as u32, opts)
                .expect("cluster model"),
        ) as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(CommitteeStdUtils::new(0.2, 6)) as Box<dyn Utils>);

    let report = Workflow::new(setting).run(KernelSet { generators, oracles, model, utils })?;

    println!("=== PAL inorganic clusters (paper §3.3, Fig. 3c) ===");
    println!("clusters            : Bi{N_ATOMS}-like, charges {CHARGES:?}");
    println!("exchange iterations : {}", report.al_iterations);
    println!("DFT-sim labels      : {}", report.oracle_labels);
    println!("retraining rounds   : {}", report.retrain_rounds);
    println!("wall time           : {:.2}s", report.wall.as_secs_f64());
    let manager = &report.kernel("manager")[0];
    println!(
        "dynamic oracle list : {} adjustments, {} queue entries dropped",
        manager.counter("adjustments"),
        manager.counter("adjusted_dropped"),
    );
    println!("final losses        : {:?}", report.final_losses);
    Ok(())
}
