//! Thermo-fluid flow optimization (paper §3.4, Fig. 3d):
//! particle-swarm generators place eddy promoters in a 2-D channel, the
//! CNN-surrogate committee predicts (C_f, St), and a reduced-order
//! channel-flow model stands in for the in-house OpenFOAM solver. All three
//! kernel costs are balanced — the SI use-case-3 regime where PAL
//! approaches its 3x bound.
//!
//! ```sh
//! make artifacts && cargo run --release --example thermofluid
//! ```

use std::sync::Arc;
use std::time::Duration;

use pal::config::{AlSetting, StopCriteria};
use pal::coordinator::selection::CommitteeStdUtils;
use pal::coordinator::workflow::Workflow;
use pal::kernels::generators::PsoGenerator;
use pal::kernels::models::HloSurrogateModel;
use pal::kernels::oracles::{ChannelFlowOracle, LatencyOracle};
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::runtime::{default_artifacts_dir, Manifest};

const GRID: usize = 16; // surrogate1 artifact grid

fn main() -> anyhow::Result<()> {
    let setting = AlSetting {
        result_dir: "results/thermofluid".into(),
        gene_process: 8, // 8 swarm particles
        pred_process: 4,
        ml_process: 4,
        orcl_process: 4,
        retrain_size: 12,
        stop: StopCriteria {
            max_iterations: Some(200),
            max_labels: Some(96),
            max_wall: Some(Duration::from_secs(180)),
            ..Default::default()
        },
        ..Default::default()
    };

    let generators: Vec<_> = (0..setting.gene_process)
        .map(|i| {
            Box::new(move || {
                Box::new(PsoGenerator::new(GRID, 4, 300 + i as u64)) as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();

    // CFD stand-in: reduced-order channel model + balanced latency
    let oracles: Vec<_> = (0..setting.orcl_process)
        .map(|i| {
            Box::new(move || {
                Box::new(
                    LatencyOracle::new(
                        ChannelFlowOracle::new(GRID),
                        Duration::from_millis(80),
                    )
                    .with_jitter(0.2, i as u64),
                ) as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();

    let dir = default_artifacts_dir();
    let model = Arc::new(move |mode: Mode, replica: usize| {
        let manifest = Manifest::load(&dir).expect("artifacts");
        let mut m = HloSurrogateModel::new(manifest, mode, 40 + replica as u32)
            .expect("surrogate model");
        m.epochs_per_round = 24;
        Box::new(m) as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(CommitteeStdUtils::new(0.02, 6)) as Box<dyn Utils>);

    let report = Workflow::new(setting).run(KernelSet { generators, oracles, model, utils })?;

    println!("=== PAL thermo-fluid optimization (paper §3.4, Fig. 3d) ===");
    println!("swarm               : 8 PSO particles, {GRID}x{GRID} channel grid");
    println!("exchange iterations : {}", report.al_iterations);
    println!("CFD-sim labels      : {}", report.oracle_labels);
    println!("retraining rounds   : {}", report.retrain_rounds);
    println!("wall time           : {:.2}s", report.wall.as_secs_f64());
    println!(
        "surrogate latency   : {:.2} ms per committee-member forward",
        report.mean_timer_ms("prediction", "predict")
    );
    println!("final losses        : {:?}", report.final_losses);
    Ok(())
}
