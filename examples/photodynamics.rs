//! Photodynamics application (paper §3.1, Fig. 3a):
//! 89 parallel surface-hopping MD trajectories on 3 excited-state surfaces,
//! a 4-member NN committee (one member per prediction/training rank, as on
//! the paper's HoreKa deployment), and a simulated-TDDFT oracle.
//!
//! Reports the paper's §3.1 quantities: mean committee forward time per NN
//! for the 89-geometry batch, and the communication + trajectory-propagation
//! remainder of the exchange loop.
//!
//! ```sh
//! make artifacts && cargo run --release --example photodynamics
//! ```

use std::sync::Arc;
use std::time::Duration;

use pal::config::{AlSetting, StopCriteria};
use pal::coordinator::selection::CommitteeStdUtils;
use pal::coordinator::workflow::Workflow;
use pal::kernels::generators::{MdGenerator, MdLayout};
use pal::kernels::models::{HloPotentialModel, TrainOptions};
use pal::kernels::oracles::{LatencyOracle, MultiStateOracle};
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::potential::{MultiState, Pes};
use pal::rng::Rng;
use pal::runtime::{default_artifacts_dir, Manifest};

const N_ATOMS: usize = 6; // matches the photo1 artifact set
const N_STATES: usize = 3;
const N_TRAJ: usize = 89; // paper: 89 parallel MD simulations
const COMMITTEE: usize = 4; // paper: 4-NN query-by-committee

fn main() -> anyhow::Result<()> {
    let setting = AlSetting {
        result_dir: "results/photodynamics".into(),
        gene_process: N_TRAJ,
        pred_process: COMMITTEE,
        ml_process: COMMITTEE,
        orcl_process: 4,
        retrain_size: 8,
        stop: StopCriteria {
            max_iterations: Some(100),
            max_labels: Some(120),
            max_wall: Some(Duration::from_secs(180)),
            ..Default::default()
        },
        ..Default::default()
    };

    let layout = MdLayout { n_atoms: N_ATOMS, n_globals: 1, n_states: N_STATES };
    let pes = MultiState::photo(N_ATOMS, N_STATES);

    // 89 trajectories exploring different regions (different seeds, and a
    // third of them start on an excited surface)
    let generators: Vec<_> = (0..N_TRAJ)
        .map(|i| {
            let pes = pes.clone();
            Box::new(move || {
                let mut rng = Rng::new(7_000 + i as u64);
                let x0 = pes.initial_geometry(&mut rng);
                let mut md = MdGenerator::new(layout, x0, 7_000 + i as u64)
                    .with_dt(0.02)
                    .with_patience(5);
                md.set_state(i % N_STATES); // surface-hopping start states
                Box::new(md) as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();

    // TDDFT stand-in: analytic multi-state PES + simulated QC latency
    let oracles: Vec<_> = (0..setting.orcl_process)
        .map(|i| {
            let pes = pes.clone();
            Box::new(move || {
                Box::new(
                    LatencyOracle::new(
                        MultiStateOracle::new(pes, 1),
                        Duration::from_millis(150),
                    )
                    .with_jitter(0.2, i as u64),
                ) as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();

    let dir = default_artifacts_dir();
    let model = Arc::new(move |mode: Mode, replica: usize| {
        let manifest = Manifest::load(&dir).expect("artifacts");
        let opts = TrainOptions { epochs_per_round: 16, ..Default::default() };
        Box::new(
            HloPotentialModel::new(manifest, "photo1", mode, 20 + replica as u32, opts)
                .expect("photo model"),
        ) as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(CommitteeStdUtils::new(0.08, 8)) as Box<dyn Utils>);

    let report = Workflow::new(setting).run(KernelSet { generators, oracles, model, utils })?;

    // §3.1-style latency breakdown
    let fwd_ms = report.mean_timer_ms("prediction", "predict");
    let comm_ms = report.mean_timer_ms("exchange", "gather_gen")
        + report.mean_timer_ms("exchange", "bcast_pred")
        + report.mean_timer_ms("exchange", "scatter_gene")
        + report.mean_timer_ms("exchange", "prediction_check");
    let gen_ms = report.mean_timer_ms("generator", "generate");

    println!("=== PAL photodynamics (paper §3.1, Fig. 3a) ===");
    println!("trajectories        : {N_TRAJ} (batch per committee forward)");
    println!("committee           : {COMMITTEE} NNs (1 per prediction rank)");
    println!("exchange iterations : {}", report.al_iterations);
    println!("TDDFT-sim labels    : {}", report.oracle_labels);
    println!("retraining rounds   : {}", report.retrain_rounds);
    println!();
    println!("-- §3.1 latency breakdown (paper: 51.5 ms fwd, 4.27 ms comm+prop) --");
    println!("committee forward   : {fwd_ms:.2} ms per NN per 89-geometry batch");
    println!("comm + check        : {comm_ms:.2} ms per iteration");
    println!("MD propagation      : {gen_ms:.3} ms per trajectory step");
    println!(
        "comm/forward ratio  : {:.3} (paper: {:.3})",
        comm_ms / fwd_ms.max(1e-9),
        4.27 / 51.5
    );
    Ok(())
}
