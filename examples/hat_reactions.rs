//! HAT reaction simulations (paper §3.2, Fig. 3b):
//! biased reaction-path samplers stream diverse geometries across the
//! Müller-Brown surface (the transition-state-search stand-in), a cheap
//! xTB-like oracle labels them, and the GNN-committee stand-in trains on a
//! **rolling window** — the SI use-case-2 recommendation ("newly incoming
//! xTB-labeled samples are added ... old samples are removed").
//!
//! Demonstrates a *user-defined* kernel: `EmbeddedHatSampler` wraps the
//! library's `BiasedSampler`, embedding the 2-D reactive coordinate into a
//! 3-atom geometry (two fixed reference atoms + the moving H) so the
//! rotation-invariant RBF descriptor can resolve it.
//!
//! ```sh
//! make artifacts && cargo run --release --example hat_reactions
//! ```

use std::sync::Arc;
use std::time::Duration;

use pal::config::{AlSetting, StopCriteria};
use pal::coordinator::selection::CommitteeStdUtils;
use pal::coordinator::workflow::Workflow;
use pal::kernels::generators::BiasedSampler;
use pal::kernels::models::{HloPotentialModel, TrainOptions};
use pal::kernels::oracles::LatencyOracle;
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::potential::{MullerBrown, Pes};
use pal::runtime::{default_artifacts_dir, Manifest};

/// 3-atom embedding: atom0 = origin, atom1 = (1,0,0) reference frame,
/// atom2 = the migrating hydrogen at the reactive coordinate (x, y).
fn embed(x: f32, y: f32) -> Vec<f32> {
    vec![
        0.0, 0.0, 0.0, // reference atom A
        1.0, 0.0, 0.0, // reference atom B
        x, y, 0.0, // migrating H
        0.0, // global feature (unused)
        1.0, // single ground state
    ]
}

/// User-defined generator: BiasedSampler paths, embedded for the model.
struct EmbeddedHatSampler {
    inner: BiasedSampler,
}

impl Generator for EmbeddedHatSampler {
    fn generate_new_data(&mut self, data_to_gene: Option<&[f32]>) -> (bool, Vec<f32>) {
        let (stop, raw) = self.inner.generate_new_data(data_to_gene);
        (stop, embed(raw[0], raw[1]))
    }
}

/// xTB stand-in: Müller-Brown energy + forces on the embedded geometry.
struct HatOracle {
    mb: MullerBrown,
}

impl Oracle for HatOracle {
    fn run_calc(&mut self, input: &[f32]) -> Vec<f32> {
        let (x, y) = (input[6], input[7]);
        let e = self.mb.energy(&[x, y, 0.0]) as f32;
        let f2 = self.mb.forces(&[x, y, 0.0]);
        // label layout [e (1), f (9)]: forces only on the H atom
        let mut out = vec![e, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        out.extend_from_slice(&f2);
        out
    }
}

fn main() -> anyhow::Result<()> {
    let setting = AlSetting {
        result_dir: "results/hat".into(),
        gene_process: 12,
        pred_process: 3,
        ml_process: 3,
        orcl_process: 6, // cheap oracle → many workers (SI use case 2)
        retrain_size: 16,
        stop: StopCriteria {
            max_iterations: Some(400),
            max_labels: Some(240),
            max_wall: Some(Duration::from_secs(180)),
            ..Default::default()
        },
        ..Default::default()
    };

    let generators: Vec<_> = (0..setting.gene_process)
        .map(|i| {
            Box::new(move || {
                Box::new(EmbeddedHatSampler { inner: BiasedSampler::new(500 + i as u64) })
                    as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();

    let oracles: Vec<_> = (0..setting.orcl_process)
        .map(|i| {
            Box::new(move || {
                // xTB ≈ 10 s in the paper; scaled to 10 ms here (ratios are
                // what the workflow dynamics respond to)
                Box::new(
                    LatencyOracle::new(
                        HatOracle { mb: MullerBrown::default() },
                        Duration::from_millis(10),
                    )
                    .with_jitter(0.3, i as u64),
                ) as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();

    let dir = default_artifacts_dir();
    let model = Arc::new(move |mode: Mode, replica: usize| {
        let manifest = Manifest::load(&dir).expect("artifacts");
        let opts = TrainOptions {
            epochs_per_round: 24,
            rolling_window: Some(160), // SI use case 2: bounded training set
            ..Default::default()
        };
        Box::new(
            HloPotentialModel::new(manifest, "hat1", mode, 60 + replica as u32, opts)
                .expect("hat model"),
        ) as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(CommitteeStdUtils::new(0.08, 8)) as Box<dyn Utils>);

    let report = Workflow::new(setting).run(KernelSet { generators, oracles, model, utils })?;

    println!("=== PAL HAT reactions (paper §3.2, Fig. 3b) ===");
    println!("samplers            : 12 biased reaction-path walkers");
    println!("exchange iterations : {}", report.al_iterations);
    println!("xTB-sim labels      : {}", report.oracle_labels);
    println!("retraining rounds   : {} (rolling window: 160)", report.retrain_rounds);
    println!("wall time           : {:.2}s", report.wall.as_secs_f64());
    println!("final losses        : {:?}", report.final_losses);
    println!(
        "per-oracle labels   : {:?}",
        report
            .kernel("oracle")
            .iter()
            .map(|k| k.counter("labels"))
            .collect::<Vec<_>>()
    );
    Ok(())
}
