//! Quickstart: the SI §S3 toy workflow, end to end.
//!
//! 20 random-number generators, 3 prediction + 3 training processes hosting
//! the HLO toy committee (linear 4→4, AOT-compiled from JAX), 5 oracles
//! labeling with a sin map, std-threshold selection.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::sync::Arc;
use std::time::Duration;

use pal::config::{AlSetting, StopCriteria};
use pal::coordinator::selection::CommitteeStdUtils;
use pal::coordinator::workflow::Workflow;
use pal::kernels::generators::RandomGenerator;
use pal::kernels::models::HloToyModel;
use pal::kernels::{Generator, KernelSet, Mode, Model, Oracle, Utils};
use pal::runtime::{default_artifacts_dir, Manifest};
use pal::sim::workload::SyntheticOracle;

fn main() -> anyhow::Result<()> {
    // the SI example's process counts (scaled-down stop criteria)
    let setting = AlSetting {
        result_dir: "results/quickstart".into(),
        pred_process: 3,
        orcl_process: 5,
        gene_process: 20,
        ml_process: 3,
        retrain_size: 20,
        stop: StopCriteria {
            max_iterations: Some(300),
            max_labels: Some(200),
            max_wall: Some(Duration::from_secs(60)),
            ..Default::default()
        },
        ..Default::default()
    };

    let generators: Vec<_> = (0..setting.gene_process)
        .map(|i| {
            let seed = i as u64;
            Box::new(move || {
                // the SI toy generator: limit 300000 + rank
                Box::new(RandomGenerator::new(4, 300_000 + seed, seed)) as Box<dyn Generator>
            }) as Box<dyn FnOnce() -> Box<dyn Generator> + Send>
        })
        .collect();

    let oracles: Vec<_> = (0..setting.orcl_process)
        .map(|_| {
            Box::new(|| {
                Box::new(SyntheticOracle { label_cost: Duration::from_millis(5), out_dim: 4 })
                    as Box<dyn Oracle>
            }) as Box<dyn FnOnce() -> Box<dyn Oracle> + Send>
        })
        .collect();

    let dir = default_artifacts_dir();
    let model = Arc::new(move |mode: Mode, replica: usize| {
        let manifest = Manifest::load(&dir).expect("run `make artifacts` first");
        Box::new(HloToyModel::new(manifest, mode, replica as u32).expect("toy model"))
            as Box<dyn Model>
    });
    let utils = Arc::new(|| Box::new(CommitteeStdUtils::new(0.05, 10)) as Box<dyn Utils>);

    let report = Workflow::new(setting).run(KernelSet { generators, oracles, model, utils })?;

    println!("=== PAL quickstart (SI §S3 toy) ===");
    println!("exchange iterations : {}", report.al_iterations);
    println!("oracle labels       : {}", report.oracle_labels);
    println!("retraining rounds   : {}", report.retrain_rounds);
    println!("wall time           : {:.2}s", report.wall.as_secs_f64());
    println!(
        "prediction latency  : {:.3} ms/batch (committee of {})",
        report.mean_timer_ms("prediction", "predict"),
        3
    );
    println!(
        "comm               : {} messages, {} KiB",
        report.messages,
        report.payload_bytes / 1024
    );
    println!(
        "final losses        : {:?}",
        report.final_losses
    );
    Ok(())
}
