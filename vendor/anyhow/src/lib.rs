//! Minimal, offline, API-compatible subset of the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides exactly the surface the `pal` crate uses: [`Error`],
//! [`Result`], the [`Context`] extension trait (on `Result` and `Option`),
//! and the `anyhow!` / `bail!` / `ensure!` macros. Error values keep a
//! simple context chain: `Display` shows the outermost message, `{:#}`
//! joins the whole chain with `": "`, and `Debug` renders a `Caused by:`
//! list — the same conventions real `anyhow` users rely on.

use std::fmt;

/// A context-chained error. `chain[0]` is the outermost message.
pub struct Error {
    chain: Vec<String>,
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    fn wrap(mut self, context: String) -> Self {
        self.chain.insert(0, context);
        self
    }

    /// Add context (outermost first), mirroring `anyhow::Error::context`.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        self.wrap(context.to_string())
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait attaching context to fallible values.
pub trait Context<T, E>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).wrap(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).wrap(f().to_string()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.wrap(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.wrap(f().to_string()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)+) => {
        $crate::Error::msg(format!($fmt, $($arg)+))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)+) => {
        return Err($crate::anyhow!($($tt)+).into())
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: `", stringify!($cond), "`")).into());
        }
    };
    ($cond:expr, $($rest:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($rest)+).into());
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Error = Err::<(), _>(io_err()).context("loading config").unwrap_err();
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: missing");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let e = None::<u32>.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");
        assert_eq!(Some(3u32).context("empty").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too large: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too large: 11");
        let e = anyhow!("plain {}", 7);
        assert_eq!(format!("{e}"), "plain 7");
    }

    #[test]
    fn anyhow_result_context() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }
}
