"""AOT compile path: lower every L2 entry point to HLO text + manifest.

Run once via ``make artifacts`` (``python -m compile.aot --out-dir ../artifacts``).
The rust runtime (`rust/src/runtime/`) consumes ``manifest.json`` and the
``*.hlo.txt`` files; Python is never imported at runtime.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the pinned
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
All entry points are lowered with ``return_tuple=True`` and the rust side
unwraps the tuple.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import descriptor as desc_kernel
from .kernels import committee_mlp as cmlp_kernel

F32, U32 = "f32", "u32"


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the xla-0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape: Sequence[int], dtype: str = F32) -> jax.ShapeDtypeStruct:
    jdt = jnp.float32 if dtype == F32 else jnp.uint32
    return jax.ShapeDtypeStruct(tuple(shape), jdt)


class Exporter:
    """Collects artifact entries and writes HLO text + manifest.json."""

    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries: List[Dict] = []

    def add(self, name: str, fn: Callable,
            inputs: List[Tuple[str, Sequence[int], str]],
            outputs: List[Tuple[str, Sequence[int]]],
            meta: Dict) -> None:
        """Lower ``fn`` at the given input specs and record the entry."""
        specs = [_spec(shape, dt) for (_, shape, dt) in inputs]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        self.entries.append({
            "name": name,
            "file": fname,
            "inputs": [{"name": n, "shape": list(s), "dtype": dt}
                       for (n, s, dt) in inputs],
            "outputs": [{"name": n, "shape": list(s), "dtype": F32}
                        for (n, s) in outputs],
            "meta": meta,
        })
        print(f"  {name}: {len(text)} chars")

    def finish(self) -> None:
        manifest = {"version": 1, "entries": self.entries}
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        print(f"wrote manifest with {len(self.entries)} entries")


# --------------------------------------------------------------------------
# Export sets
# --------------------------------------------------------------------------


def export_potential(ex: Exporter, tag: str, cfg: model.PotentialConfig,
                     fwd_batches: Sequence[int], euq_batches: Sequence[int],
                     train_batch: int) -> None:
    m, p, n3 = cfg.n_members, cfg.param_size, cfg.n_atoms * 3
    g, s = cfg.n_globals, cfg.n_states
    meta = {
        "kind": "potential", "tag": tag,
        "n_atoms": cfg.n_atoms, "n_rbf": cfg.n_rbf, "hidden": cfg.hidden,
        "n_members": m, "n_states": s, "n_globals": g,
        "param_size": p, "opt_size": cfg.opt_size,
        "lr": cfg.lr, "force_weight": cfg.force_weight,
        "vmem_descriptor_bytes": desc_kernel.vmem_estimate_bytes(
            cfg.n_atoms, cfg.n_rbf),
    }
    for b in fwd_batches:
        ex.add(
            f"potential_{tag}_fwd_b{b}",
            functools.partial(model.potential_fwd, cfg=cfg),
            inputs=[("w_all", [m * p], F32), ("x", [b, n3], F32),
                    ("g", [b, g], F32), ("s", [b, s], F32)],
            outputs=[("e_all", [m, b, s]), ("e_mean", [b, s]),
                     ("e_std", [b, s]), ("f_mean", [b, n3]),
                     ("f_std", [b, n3])],
            meta={**meta, "batch": b, "entry": "fwd"},
        )
    for b in euq_batches:
        ex.add(
            f"potential_{tag}_euq_b{b}",
            functools.partial(model.potential_euq, cfg=cfg),
            inputs=[("w_all", [m * p], F32), ("x", [b, n3], F32),
                    ("g", [b, g], F32)],
            outputs=[("e_all", [m, b, s]), ("e_mean", [b, s]),
                     ("e_std", [b, s])],
            meta={**meta, "batch": b, "entry": "euq",
                  "vmem_committee_bytes": cmlp_kernel.vmem_estimate_bytes(
                      b, cfg.n_atoms, cfg.feat_dim, cfg.hidden, s),
                  "mxu_utilization": cmlp_kernel.mxu_utilization_estimate(
                      b, cfg.n_atoms, cfg.feat_dim, cfg.hidden)},
        )
    t = train_batch
    ex.add(
        f"potential_{tag}_train_t{t}",
        functools.partial(model.potential_train_step, cfg=cfg),
        inputs=[("w", [p], F32), ("opt", [cfg.opt_size], F32),
                ("x", [t, n3], F32), ("g", [t, g], F32), ("s", [t, s], F32),
                ("y_e", [t, s], F32), ("y_f", [t, n3], F32)],
        outputs=[("w2", [p]), ("opt2", [cfg.opt_size]), ("loss", [1])],
        meta={**meta, "batch": t, "entry": "train"},
    )
    ex.add(
        f"potential_{tag}_init",
        functools.partial(model.potential_init, cfg=cfg),
        inputs=[("seed", [], U32)],
        outputs=[("w_all", [m * p])],
        meta={**meta, "entry": "init"},
    )


def export_surrogate(ex: Exporter, cfg: model.SurrogateConfig,
                     fwd_batches: Sequence[int], train_batch: int,
                     prefix: str = "surrogate") -> None:
    m, p, gr, o = cfg.n_members, cfg.param_size, cfg.grid, cfg.n_out
    meta = {
        "kind": "surrogate", "tag": prefix, "grid": gr, "channels": cfg.channels,
        "dense": cfg.dense, "n_members": m, "n_out": o,
        "param_size": p, "opt_size": cfg.opt_size, "lr": cfg.lr,
    }
    for b in fwd_batches:
        ex.add(
            f"{prefix}_fwd_b{b}",
            functools.partial(model.surrogate_fwd, cfg=cfg),
            inputs=[("w_all", [m * p], F32), ("grid", [b, gr, gr], F32)],
            outputs=[("y_all", [m, b, o]), ("y_mean", [b, o]),
                     ("y_std", [b, o])],
            meta={**meta, "batch": b, "entry": "fwd"},
        )
    t = train_batch
    ex.add(
        f"{prefix}_train_t{t}",
        functools.partial(model.surrogate_train_step, cfg=cfg),
        inputs=[("w", [p], F32), ("opt", [cfg.opt_size], F32),
                ("grid", [t, gr, gr], F32), ("y", [t, o], F32)],
        outputs=[("w2", [p]), ("opt2", [cfg.opt_size]), ("loss", [1])],
        meta={**meta, "batch": t, "entry": "train"},
    )
    ex.add(
        f"{prefix}_init",
        functools.partial(model.surrogate_init, cfg=cfg),
        inputs=[("seed", [], U32)],
        outputs=[("w_all", [m * p])],
        meta={**meta, "entry": "init"},
    )


def export_toy(ex: Exporter, cfg: model.ToyConfig,
               fwd_batches: Sequence[int], train_batch: int) -> None:
    m, p = cfg.n_members, cfg.param_size
    meta = {
        "kind": "toy", "tag": "toy", "n_in": cfg.n_in, "n_out": cfg.n_out,
        "n_members": m, "param_size": p, "opt_size": cfg.opt_size,
        "lr": cfg.lr,
    }
    for b in fwd_batches:
        ex.add(
            f"toy_fwd_b{b}",
            functools.partial(model.toy_fwd, cfg=cfg),
            inputs=[("w_all", [m * p], F32), ("x", [b, cfg.n_in], F32)],
            outputs=[("y_all", [m, b, cfg.n_out]), ("y_mean", [b, cfg.n_out]),
                     ("y_std", [b, cfg.n_out])],
            meta={**meta, "batch": b, "entry": "fwd"},
        )
    t = train_batch
    ex.add(
        f"toy_train_t{t}",
        functools.partial(model.toy_train_step, cfg=cfg),
        inputs=[("w", [p], F32), ("opt", [cfg.opt_size], F32),
                ("x", [t, cfg.n_in], F32), ("y", [t, cfg.n_out], F32)],
        outputs=[("w2", [p]), ("opt2", [cfg.opt_size]), ("loss", [1])],
        meta={**meta, "batch": t, "entry": "train"},
    )
    ex.add(
        "toy_init",
        functools.partial(model.toy_init, cfg=cfg),
        inputs=[("seed", [], U32)],
        outputs=[("w_all", [m * p])],
        meta={**meta, "entry": "init"},
    )


# Canonical configs — keep in sync with rust examples (they look these up
# through the manifest, so shape changes here propagate automatically).
#
# Committee (n_members>1) variants compute fused committee statistics in one
# call (used by the fused-path benches). Single-member (*1) variants back the
# paper-faithful protocol where each prediction/training MPI rank owns one
# committee member and the controller aggregates across ranks.
GROUND = model.PotentialConfig(n_atoms=8, n_rbf=16, hidden=32, n_members=4,
                               n_states=1, n_globals=1)
GROUND1 = model.PotentialConfig(n_atoms=8, n_rbf=16, hidden=32, n_members=1,
                                n_states=1, n_globals=1)
PHOTO = model.PotentialConfig(n_atoms=6, n_rbf=16, hidden=32, n_members=4,
                              n_states=3, n_globals=1)
PHOTO1 = model.PotentialConfig(n_atoms=6, n_rbf=16, hidden=32, n_members=1,
                               n_states=3, n_globals=1)
DIMER = model.PotentialConfig(n_atoms=2, n_rbf=8, hidden=16, n_members=4,
                              n_states=1, n_globals=1)
DIMER1 = model.PotentialConfig(n_atoms=2, n_rbf=8, hidden=16, n_members=1,
                               n_states=1, n_globals=1)
# HAT reaction-path model: 3-atom embedding of a 2-D reactive surface
# (two fixed reference atoms + the moving H), see examples/hat_reactions.rs
HAT1 = model.PotentialConfig(n_atoms=3, n_rbf=8, hidden=16, n_members=1,
                             n_states=1, n_globals=1)
CFD = model.SurrogateConfig()
CFD1 = model.SurrogateConfig(n_members=1)
TOY = model.ToyConfig()
TOY1 = model.ToyConfig(n_members=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: ground,photo,dimer,cfd,toy")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    sets = (args.only.split(",") if args.only
            else ["ground", "photo", "dimer", "cfd", "toy"])

    ex = Exporter(args.out_dir)
    if "ground" in sets:
        export_potential(ex, "ground", GROUND,
                         fwd_batches=[1, 16, 89], euq_batches=[16],
                         train_batch=32)
        export_potential(ex, "ground1", GROUND1,
                         fwd_batches=[1, 16, 89], euq_batches=[16],
                         train_batch=32)
    if "photo" in sets:
        export_potential(ex, "photo", PHOTO,
                         fwd_batches=[89], euq_batches=[89], train_batch=32)
        export_potential(ex, "photo1", PHOTO1,
                         fwd_batches=[89], euq_batches=[89], train_batch=32)
    if "dimer" in sets:
        export_potential(ex, "dimer", DIMER,
                         fwd_batches=[1, 8], euq_batches=[8], train_batch=16)
        export_potential(ex, "dimer1", DIMER1,
                         fwd_batches=[1, 8], euq_batches=[8], train_batch=16)
        export_potential(ex, "hat1", HAT1,
                         fwd_batches=[1, 8], euq_batches=[8], train_batch=16)
    if "cfd" in sets:
        export_surrogate(ex, CFD, fwd_batches=[8, 32], train_batch=16)
        export_surrogate(ex, CFD1, fwd_batches=[8, 32], train_batch=16,
                         prefix="surrogate1")
    if "toy" in sets:
        export_toy(ex, TOY, fwd_batches=[20], train_batch=10)
    ex.finish()


if __name__ == "__main__":
    main()
