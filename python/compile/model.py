"""L2: JAX compute graphs for PAL's machine-learned models (build-time only).

Three model families, all exported AOT to HLO text by ``aot.py`` and executed
from the rust coordinator via PJRT; Python never runs on the request path.

1. **Potential**: RBF-descriptor (L1 Pallas kernel) → per-atom tanh MLP →
   total energy; forces via autodiff; query-by-committee of M members.
   Used by the photodynamics / HAT / cluster applications (Table 1).
2. **Surrogate**: small CNN grid → (C_f, St) committee for the thermo-fluid
   application (Table 1, Fig. 3d).
3. **Toy**: the SI toy model (4 → 4 linear), used by the quickstart example
   and the comm-protocol tests.

State convention (mirrors the paper's SI §S4 ``get_weight``/``update``):
*all* model and optimizer state crosses the rust↔HLO boundary as flat 1-D
f32 arrays. Member ``i`` of the committee owns ``w_flat[i*P:(i+1)*P]``.
Adam state per member is ``[m (P), v (P), t (1)]`` (length 2P+1).

Gradients: inference artifacts differentiate through the Pallas descriptor
via its ``custom_vjp`` (forward = Pallas, backward = reference transpose).
The training artifact needs second-order structure (d/dw of forces which are
d/dx), so it uses the pure-jnp reference descriptor throughout — numerically
identical, and ``custom_vjp`` does not support grad-of-grad.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import ref
from .kernels.descriptor import descriptor
from .kernels.committee_mlp import committee_mlp

# --------------------------------------------------------------------------
# Configs
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PotentialConfig:
    """Shape parameters of the committee potential (fixed per artifact)."""

    n_atoms: int = 8
    n_rbf: int = 16
    hidden: int = 32
    n_members: int = 4
    n_states: int = 1    # >1 for excited-state (photodynamics) PES
    n_globals: int = 1   # global scalar features (e.g. cluster charge)
    lr: float = 1e-3
    force_weight: float = 0.1

    @property
    def feat_dim(self) -> int:
        return self.n_rbf + self.n_globals

    @property
    def layer_shapes(self) -> List[Tuple[int, ...]]:
        d, h, s = self.feat_dim, self.hidden, self.n_states
        return [(d, h), (h,), (h, h), (h,), (h, s), (s,)]

    @property
    def param_size(self) -> int:
        total = 0
        for s in self.layer_shapes:
            n = 1
            for d in s:
                n *= d
            total += n
        return total

    @property
    def opt_size(self) -> int:
        return 2 * self.param_size + 1


@dataclasses.dataclass(frozen=True)
class SurrogateConfig:
    """Shape parameters of the CNN thermo-fluid surrogate."""

    grid: int = 16       # H = W
    channels: int = 8
    dense: int = 32
    n_members: int = 4
    n_out: int = 2       # (C_f, St)
    lr: float = 1e-3

    @property
    def layer_shapes(self) -> List[Tuple[int, ...]]:
        c, d, o = self.channels, self.dense, self.n_out
        g = self.grid // 4  # two 2x2 poolings
        return [
            (3, 3, 1, c), (c,),          # conv1 HWIO
            (3, 3, c, c), (c,),          # conv2
            (g * g * c, d), (d,),        # dense
            (d, o), (o,),                # head
        ]

    @property
    def param_size(self) -> int:
        total = 0
        for s in self.layer_shapes:
            n = 1
            for d in s:
                n *= d
            total += n
        return total

    @property
    def opt_size(self) -> int:
        return 2 * self.param_size + 1


@dataclasses.dataclass(frozen=True)
class ToyConfig:
    """The SI §S4 toy model: linear 4 → 4."""

    n_in: int = 4
    n_out: int = 4
    n_members: int = 3
    lr: float = 1e-2

    @property
    def layer_shapes(self) -> List[Tuple[int, ...]]:
        return [(self.n_in, self.n_out), (self.n_out,)]

    @property
    def param_size(self) -> int:
        return self.n_in * self.n_out + self.n_out

    @property
    def opt_size(self) -> int:
        return 2 * self.param_size + 1


# --------------------------------------------------------------------------
# Flat-weight plumbing
# --------------------------------------------------------------------------


def unflatten(w: jnp.ndarray, shapes: List[Tuple[int, ...]]) -> List[jnp.ndarray]:
    """Split a flat (P,) weight vector into the layer tensors of ``shapes``."""
    out, off = [], 0
    for s in shapes:
        n = 1
        for d in s:
            n *= d
        out.append(w[off:off + n].reshape(s))
        off += n
    return out


def members_view(w_all: jnp.ndarray, n_members: int, param_size: int) -> jnp.ndarray:
    """(M*P,) → (M, P)."""
    return w_all.reshape(n_members, param_size)


def stack_member_layers(w_all: jnp.ndarray, n_members: int,
                        shapes: List[Tuple[int, ...]]) -> List[jnp.ndarray]:
    """(M*P,) → list of (M, *shape) stacked layer tensors."""
    p = 0
    for s in shapes:
        n = 1
        for d in s:
            n *= d
        p += n
    wm = members_view(w_all, n_members, p)
    stacked, off = [], 0
    for s in shapes:
        n = 1
        for d in s:
            n *= d
        stacked.append(wm[:, off:off + n].reshape((n_members,) + s))
        off += n
    return stacked


def committee_stats(y_all: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean and ddof=1 std over the leading committee axis (paper's np.std)."""
    m = y_all.shape[0]
    mean = jnp.mean(y_all, axis=0)
    if m > 1:
        var = jnp.sum((y_all - mean[None]) ** 2, axis=0) / (m - 1)
    else:
        var = jnp.zeros_like(mean)
    return mean, jnp.sqrt(var)


# --------------------------------------------------------------------------
# Adam (shared by all train steps)
# --------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def adam_step(w: jnp.ndarray, opt: jnp.ndarray, grad: jnp.ndarray,
              lr: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One Adam update on flat weights. ``opt = [m, v, t]``."""
    p = w.shape[0]
    m, v, t = opt[:p], opt[p:2 * p], opt[2 * p]
    t = t + 1.0
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * grad
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * grad * grad
    mhat = m / (1.0 - ADAM_B1 ** t)
    vhat = v / (1.0 - ADAM_B2 ** t)
    w2 = w - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return w2, jnp.concatenate([m, v, t[None]])


# --------------------------------------------------------------------------
# Potential model
# --------------------------------------------------------------------------


def build_features(x: jnp.ndarray, g: jnp.ndarray, cfg: PotentialConfig,
                   use_pallas: bool) -> jnp.ndarray:
    """(B, N*3) coords + (B, G) globals → (B, N, K+G) per-atom features."""
    b = x.shape[0]
    xs = x.reshape(b, cfg.n_atoms, 3)
    if use_pallas:
        feats = descriptor(xs, cfg.n_rbf)                    # L1 kernel
    else:
        feats = ref.descriptor_ref(xs, cfg.n_rbf)            # 2nd-order-safe
    gb = jnp.broadcast_to(g[:, None, :], (b, cfg.n_atoms, cfg.n_globals))
    return jnp.concatenate([feats, gb], axis=-1)


def _committee_energies(w_all: jnp.ndarray, feats: jnp.ndarray,
                        cfg: PotentialConfig) -> jnp.ndarray:
    """Differentiable committee energies (M, B, S) from stacked flat weights."""
    w1, b1, w2, b2, w3, b3 = stack_member_layers(
        w_all, cfg.n_members, cfg.layer_shapes)
    return ref.committee_mlp_ref(feats, w1, b1, w2, b2, w3, b3)


def potential_fwd(w_all: jnp.ndarray, x: jnp.ndarray, g: jnp.ndarray,
                  s: jnp.ndarray, cfg: PotentialConfig):
    """Full inference entry point (the request-path artifact).

    Args:
      w_all: (M*P,) committee weights.
      x: (B, N*3) coordinates.
      g: (B, G) global features (charge, ...).
      s: (B, S) state weights (one-hot active PES for photodynamics;
         all-ones column for ground-state models).

    Returns (tuple of 5):
      e_all  (M, B, S) per-member energies,
      e_mean (B, S), e_std (B, S) committee statistics,
      f_mean (B, N*3) mean forces on the state-weighted PES,
      f_std  (B, N*3) committee force std (ddof=1).
    """

    def member_weighted_sum(xx):
        feats = build_features(xx, g, cfg, use_pallas=True)
        e_all = _committee_energies(w_all, feats, cfg)       # (M, B, S)
        return jnp.sum(e_all * s[None], axis=(1, 2)), e_all  # (M,), aux

    # jacrev gives per-member forces in one sweep: (M, B, N*3)
    jac, e_all = jax.jacrev(member_weighted_sum, has_aux=True)(x)
    f_all = -jac
    e_mean, e_std = committee_stats(e_all)
    f_mean, f_std = committee_stats(f_all)
    return e_all, e_mean, e_std, f_mean, f_std


def potential_euq(w_all: jnp.ndarray, x: jnp.ndarray, g: jnp.ndarray,
                  cfg: PotentialConfig):
    """Energy+UQ-only path (no forces) through the fused L1 committee kernel.

    Backs ``adjust_input_for_oracle`` re-scoring, where only prediction
    spread matters. Returns (e_all, e_mean, e_std).
    """
    feats = build_features(x, g, cfg, use_pallas=True)
    w1, b1, w2, b2, w3, b3 = stack_member_layers(
        w_all, cfg.n_members, cfg.layer_shapes)
    e_all = committee_mlp(feats, w1, b1, w2, b2, w3, b3)
    e_mean, e_std = committee_stats(e_all)
    return e_all, e_mean, e_std


def potential_loss(w: jnp.ndarray, x: jnp.ndarray, g: jnp.ndarray,
                   s: jnp.ndarray, y_e: jnp.ndarray, y_f: jnp.ndarray,
                   cfg: PotentialConfig) -> jnp.ndarray:
    """Single-member loss: energy MSE over all states + weighted force MSE."""
    feats = build_features(x, g, cfg, use_pallas=False)
    w1, b1, w2, b2, w3, b3 = unflatten(w, cfg.layer_shapes)
    e = ref.committee_mlp_ref(feats, w1[None], b1[None], w2[None], b2[None],
                              w3[None], b3[None])[0]         # (T, S)

    def weighted_total(xx):
        f2 = build_features(xx, g, cfg, use_pallas=False)
        ee = ref.committee_mlp_ref(f2, w1[None], b1[None], w2[None],
                                   b2[None], w3[None], b3[None])[0]
        return jnp.sum(ee * s)

    forces = -jax.grad(weighted_total)(x)                    # (T, N*3)
    loss_e = jnp.mean((e - y_e) ** 2)
    loss_f = jnp.mean((forces - y_f) ** 2)
    return loss_e + cfg.force_weight * loss_f


def potential_train_step(w: jnp.ndarray, opt: jnp.ndarray, x: jnp.ndarray,
                         g: jnp.ndarray, s: jnp.ndarray, y_e: jnp.ndarray,
                         y_f: jnp.ndarray, cfg: PotentialConfig):
    """One Adam step for one committee member.

    Returns (w', opt', loss) — loss is pre-update, so callers can log the
    descent curve without an extra forward.
    """
    loss, grad = jax.value_and_grad(potential_loss)(w, x, g, s, y_e, y_f, cfg)
    w2, opt2 = adam_step(w, opt, grad, cfg.lr)
    return w2, opt2, loss[None]


def potential_init(seed: jnp.ndarray, cfg: PotentialConfig) -> jnp.ndarray:
    """Committee weight init: (u32 scalar seed) → (M*P,) flat weights.

    Glorot-ish scaling per layer; each member gets an independent subkey so
    the committee has genuine weight diversity (query-by-committee needs it).
    """
    key = jax.random.PRNGKey(seed)
    members = []
    for i in range(cfg.n_members):
        k = jax.random.fold_in(key, i)
        parts = []
        for shape in cfg.layer_shapes:
            k, sub = jax.random.split(k)
            if len(shape) >= 2:
                fan_in = shape[0]
                parts.append(
                    (jax.random.normal(sub, shape, dtype=jnp.float32)
                     / jnp.sqrt(jnp.float32(fan_in))).reshape(-1))
            else:
                parts.append(jnp.zeros(shape, dtype=jnp.float32).reshape(-1))
        members.append(jnp.concatenate(parts))
    return jnp.concatenate(members)


# --------------------------------------------------------------------------
# CNN surrogate (thermo-fluid application)
# --------------------------------------------------------------------------


def _cnn_single(w: jnp.ndarray, grid: jnp.ndarray, cfg: SurrogateConfig):
    """One member's CNN: (P,), (B, H, W) → (B, n_out)."""
    k1, c1, k2, c2, wd, bd, wo, bo = unflatten(w, cfg.layer_shapes)
    x = grid[:, :, :, None]                                  # NHWC
    dn = lax.conv_dimension_numbers(x.shape, k1.shape, ("NHWC", "HWIO", "NHWC"))
    x = lax.conv_general_dilated(x, k1, (1, 1), "SAME", dimension_numbers=dn)
    x = jnp.maximum(x + c1, 0.0)
    x = lax.reduce_window(x, 0.0, lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID") / 4.0
    dn2 = lax.conv_dimension_numbers(x.shape, k2.shape, ("NHWC", "HWIO", "NHWC"))
    x = lax.conv_general_dilated(x, k2, (1, 1), "SAME", dimension_numbers=dn2)
    x = jnp.maximum(x + c2, 0.0)
    x = lax.reduce_window(x, 0.0, lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID") / 4.0
    x = x.reshape(x.shape[0], -1)
    x = jnp.tanh(x @ wd + bd)
    return x @ wo + bo


def surrogate_fwd(w_all: jnp.ndarray, grid: jnp.ndarray, cfg: SurrogateConfig):
    """Committee CNN inference: returns (y_all (M,B,O), y_mean, y_std)."""
    wm = members_view(w_all, cfg.n_members, cfg.param_size)
    y_all = jax.vmap(lambda w: _cnn_single(w, grid, cfg))(wm)
    y_mean, y_std = committee_stats(y_all)
    return y_all, y_mean, y_std


def surrogate_loss(w, grid, y, cfg: SurrogateConfig):
    pred = _cnn_single(w, grid, cfg)
    return jnp.mean((pred - y) ** 2)


def surrogate_train_step(w, opt, grid, y, cfg: SurrogateConfig):
    loss, grad = jax.value_and_grad(surrogate_loss)(w, grid, y, cfg)
    w2, opt2 = adam_step(w, opt, grad, cfg.lr)
    return w2, opt2, loss[None]


def surrogate_init(seed: jnp.ndarray, cfg: SurrogateConfig) -> jnp.ndarray:
    key = jax.random.PRNGKey(seed)
    members = []
    for i in range(cfg.n_members):
        k = jax.random.fold_in(key, i)
        parts = []
        for shape in cfg.layer_shapes:
            k, sub = jax.random.split(k)
            if len(shape) >= 2:
                fan_in = 1
                for d in shape[:-1]:
                    fan_in *= d
                parts.append(
                    (jax.random.normal(sub, shape, dtype=jnp.float32)
                     / jnp.sqrt(jnp.float32(fan_in))).reshape(-1))
            else:
                parts.append(jnp.zeros(shape, dtype=jnp.float32).reshape(-1))
        members.append(jnp.concatenate(parts))
    return jnp.concatenate(members)


# --------------------------------------------------------------------------
# Toy model (SI §S4 quickstart)
# --------------------------------------------------------------------------


def toy_fwd(w_all: jnp.ndarray, x: jnp.ndarray, cfg: ToyConfig):
    """Committee linear model: returns (y_all (M,B,O), y_mean, y_std)."""
    wm = members_view(w_all, cfg.n_members, cfg.param_size)

    def single(w):
        wt, b = unflatten(w, cfg.layer_shapes)
        return x @ wt + b

    y_all = jax.vmap(single)(wm)
    y_mean, y_std = committee_stats(y_all)
    return y_all, y_mean, y_std


def toy_loss(w, x, y, cfg: ToyConfig):
    wt, b = unflatten(w, cfg.layer_shapes)
    return jnp.mean((x @ wt + b - y) ** 2)


def toy_train_step(w, opt, x, y, cfg: ToyConfig):
    loss, grad = jax.value_and_grad(toy_loss)(w, x, y, cfg)
    w2, opt2 = adam_step(w, opt, grad, cfg.lr)
    return w2, opt2, loss[None]


def toy_init(seed: jnp.ndarray, cfg: ToyConfig) -> jnp.ndarray:
    key = jax.random.PRNGKey(seed)
    members = []
    for i in range(cfg.n_members):
        k = jax.random.fold_in(key, i)
        wt = jax.random.normal(k, (cfg.n_in, cfg.n_out), dtype=jnp.float32)
        wt = wt / jnp.sqrt(jnp.float32(cfg.n_in))
        members.append(jnp.concatenate(
            [wt.reshape(-1), jnp.zeros(cfg.n_out, dtype=jnp.float32)]))
    return jnp.concatenate(members)
