"""L1 Pallas kernel: fused query-by-committee MLP forward (energy-only path).

Used by the ``*_euq`` (energy + uncertainty-quantification) artifacts that
back the controller's ``adjust_input_for_oracle`` re-scoring and any
prediction path that does not need forces. The committee dimension M is the
Pallas grid: each grid step holds one member's full weight set plus the
(shared) feature tile in VMEM and emits that member's (B, S) energies, so
members never contend for VMEM and a real-TPU build runs each layer as an
MXU-resident matmul.

The gradient path is not needed here (UQ only), so no custom_vjp: this
kernel is exported exactly as lowered. Correctness oracle:
``ref.committee_mlp_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _committee_kernel(n_atoms: int,
                      f_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref,
                      out_ref):
    """One grid step = one committee member over the full batch.

    f_ref:  (B*N, D) shared feature tile (same block for every step).
    w*_ref: (1, ...) this member's weights.
    out_ref:(1, B, S) this member's total energies.
    """
    f = f_ref[...]                                        # (B*N, D)
    h1 = jnp.tanh(f @ w1_ref[0] + b1_ref[0])              # (B*N, H)
    h2 = jnp.tanh(h1 @ w2_ref[0] + b2_ref[0])             # (B*N, H)
    e = h2 @ w3_ref[0] + b3_ref[0]                        # (B*N, S)
    bn, s = e.shape
    b = bn // n_atoms
    out_ref[0] = e.reshape(b, n_atoms, s).sum(axis=1)     # (B, S)


def committee_mlp(feats: jnp.ndarray,
                  w1: jnp.ndarray, b1: jnp.ndarray,
                  w2: jnp.ndarray, b2: jnp.ndarray,
                  w3: jnp.ndarray, b3: jnp.ndarray) -> jnp.ndarray:
    """Fused committee forward.

    Args:
      feats: (B, N, D) per-atom features.
      w1: (M, D, H), b1: (M, H), w2: (M, H, H), b2: (M, H),
      w3: (M, H, S), b3: (M, S).

    Returns:
      (M, B, S) committee energies == ``ref.committee_mlp_ref``.
    """
    b, n, d = feats.shape
    m, _, h = w1.shape
    s = w3.shape[-1]
    f2 = feats.reshape(b * n, d)
    return pl.pallas_call(
        functools.partial(_committee_kernel, n),
        grid=(m,),
        in_specs=[
            pl.BlockSpec((b * n, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d, h), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, h), lambda i: (i, 0)),
            pl.BlockSpec((1, h, h), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, h), lambda i: (i, 0)),
            pl.BlockSpec((1, h, s), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, b, s), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, b, s), feats.dtype),
        interpret=True,
    )(f2, w1, b1, w2, b2, w3, b3)


def vmem_estimate_bytes(batch: int, n_atoms: int, d: int, h: int, s: int) -> int:
    """Static VMEM footprint per grid step (one member)."""
    f = 4
    bn = batch * n_atoms
    return f * (bn * d + d * h + h + h * h + h + h * s + s + 2 * bn * h + bn * s)


def mxu_utilization_estimate(batch: int, n_atoms: int, d: int, h: int) -> float:
    """MXU occupancy estimate for the dominant (B*N, D) @ (D, H) matmul.

    A 128x128 systolic tile is fully used only when every contracted and
    output dimension reaches 128; smaller dims waste the corresponding
    fraction of the array. This is the static number DESIGN.md §Perf reports
    for the TPU target (interpret-mode wallclock is not a TPU proxy).
    """
    bn = batch * n_atoms
    frac = lambda v: min(v, 128) / 128.0
    return frac(bn) * frac(d) * frac(h)
