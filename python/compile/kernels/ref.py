"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has a reference implementation here,
written with plain ``jax.numpy`` ops only. pytest (``python/tests``) sweeps
shapes/dtypes with hypothesis and asserts ``assert_allclose`` between the
kernel and its reference. The references are also used as the backward pass
of the kernels' ``custom_vjp`` (see descriptor.py) so that autodiff through
the lowered artifacts is well-defined.
"""

from __future__ import annotations

import jax.numpy as jnp

# Descriptor hyper-parameters shared by kernel + reference + model.
# Gaussian radial-basis symmetry functions (Behler-Parrinello style):
#   F[b, i, k] = sum_{j != i} exp(-(d_ij - mu_k)^2 / (2 sigma^2)) * fcut(d_ij)
R_CUT = 6.0          # radial cutoff (Angstrom-ish units of the analytic PES)
SIGMA = 0.45         # RBF width
EPS_D = 1e-12        # numerical floor for sqrt


def rbf_centers(n_rbf: int) -> jnp.ndarray:
    """Evenly spaced RBF centers on (0, R_CUT]."""
    return jnp.linspace(0.5, R_CUT, n_rbf, dtype=jnp.float32)


def cutoff_fn(d: jnp.ndarray) -> jnp.ndarray:
    """Smooth cosine cutoff: 0.5*(cos(pi d / rc) + 1) for d < rc, else 0."""
    inside = (d < R_CUT).astype(d.dtype)
    return 0.5 * (jnp.cos(jnp.pi * jnp.minimum(d, R_CUT) / R_CUT) + 1.0) * inside


def descriptor_ref(x: jnp.ndarray, n_rbf: int) -> jnp.ndarray:
    """Reference pairwise-RBF descriptor.

    Args:
      x: (B, N, 3) cartesian coordinates.
      n_rbf: number of radial basis functions K.

    Returns:
      (B, N, K) per-atom radial symmetry features.
    """
    diff = x[:, :, None, :] - x[:, None, :, :]            # (B, N, N, 3)
    d2 = jnp.sum(diff * diff, axis=-1)                    # (B, N, N)
    n = x.shape[1]
    eye = jnp.eye(n, dtype=x.dtype)
    # distance with self-pairs masked to a value beyond the cutoff
    d = jnp.sqrt(d2 + EPS_D) + eye[None] * (2.0 * R_CUT)
    mu = rbf_centers(n_rbf).astype(x.dtype)               # (K,)
    g = jnp.exp(-((d[..., None] - mu) ** 2) / (2.0 * SIGMA**2))   # (B,N,N,K)
    w = cutoff_fn(d)[..., None]                           # (B, N, N, 1)
    return jnp.sum(g * w, axis=2)                         # (B, N, K)


def committee_mlp_ref(
    feats: jnp.ndarray,
    w1: jnp.ndarray, b1: jnp.ndarray,
    w2: jnp.ndarray, b2: jnp.ndarray,
    w3: jnp.ndarray, b3: jnp.ndarray,
) -> jnp.ndarray:
    """Reference fused committee MLP: per-atom 3-layer tanh MLP, atomic sum.

    Args:
      feats: (B, N, D) per-atom features (descriptor + broadcast globals).
      w1: (M, D, H), b1: (M, H)
      w2: (M, H, H), b2: (M, H)
      w3: (M, H, S), b3: (M, S)

    Returns:
      (M, B, S) total energies per committee member and state.
    """
    b, n, d = feats.shape
    f = feats.reshape(b * n, d)
    h1 = jnp.tanh(jnp.einsum("ad,mdh->mah", f, w1) + b1[:, None, :])
    h2 = jnp.tanh(jnp.einsum("mah,mhk->mak", h1, w2) + b2[:, None, :])
    e = jnp.einsum("mah,mhs->mas", h2, w3) + b3[:, None, :]      # (M, B*N, S)
    m, _, s = e.shape
    return e.reshape(m, b, n, s).sum(axis=2)                      # (M, B, S)
