"""L1 Pallas kernel: pairwise-RBF descriptor (the inference hot-spot).

TPU-oriented structure (see DESIGN.md §Hardware-Adaptation): the grid runs
over the batch dimension, one geometry per grid step, so each step holds a
``(1, N, 3)`` coordinate tile plus the K RBF centers in VMEM and emits a
``(1, N, K)`` feature tile. On a real TPU this is the HBM→VMEM schedule the
paper's GPU implementations express with thread blocks; here we lower with
``interpret=True`` so the kernel becomes plain HLO runnable on the CPU PJRT
plugin (real-TPU lowering emits a Mosaic custom-call the CPU client cannot
execute).

Autodiff: ``pallas_call`` has no automatic VJP, but forces (−∂E/∂x) and
training both need gradients through the descriptor. We wrap the kernel in
``jax.custom_vjp`` with the backward pass derived from the pure-jnp reference
(`ref.descriptor_ref`) — forward runs the Pallas kernel, backward the
mathematically identical reference transpose.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _descriptor_kernel(n_rbf: int, x_ref, out_ref):
    """One grid step: features for a single geometry.

    x_ref:   (1, N, 3) VMEM tile of coordinates.
    out_ref: (1, N, K) VMEM tile of features.
    """
    x = x_ref[0]                                          # (N, 3)
    n = x.shape[0]
    diff = x[:, None, :] - x[None, :, :]                  # (N, N, 3)
    d2 = jnp.sum(diff * diff, axis=-1)                    # (N, N)
    eye = jnp.eye(n, dtype=x.dtype)
    d = jnp.sqrt(d2 + ref.EPS_D) + eye * (2.0 * ref.R_CUT)
    mu = ref.rbf_centers(n_rbf).astype(x.dtype)           # (K,)
    g = jnp.exp(-((d[..., None] - mu) ** 2) / (2.0 * ref.SIGMA**2))
    w = ref.cutoff_fn(d)[..., None]
    out_ref[0] = jnp.sum(g * w, axis=1)                   # (N, K)


def _descriptor_pallas(x: jnp.ndarray, n_rbf: int) -> jnp.ndarray:
    """Raw pallas_call wrapper: (B, N, 3) -> (B, N, K)."""
    b, n, _ = x.shape
    return pl.pallas_call(
        functools.partial(_descriptor_kernel, n_rbf),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, n, 3), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, n, n_rbf), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n, n_rbf), x.dtype),
        interpret=True,
    )(x)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def descriptor(x: jnp.ndarray, n_rbf: int) -> jnp.ndarray:
    """Pairwise-RBF descriptor, Pallas forward / reference backward.

    Args:
      x: (B, N, 3) coordinates.
      n_rbf: number of RBF centers (static).

    Returns:
      (B, N, K) features, identical (to float32 tolerance) to
      ``ref.descriptor_ref``.
    """
    return _descriptor_pallas(x, n_rbf)


def _descriptor_fwd(x, n_rbf):
    return _descriptor_pallas(x, n_rbf), x


def _descriptor_bwd(n_rbf, x, ct):
    _, vjp = jax.vjp(lambda xx: ref.descriptor_ref(xx, n_rbf), x)
    return (vjp(ct)[0],)


descriptor.defvjp(_descriptor_fwd, _descriptor_bwd)


def vmem_estimate_bytes(n_atoms: int, n_rbf: int) -> int:
    """Static VMEM footprint estimate for one grid step (see DESIGN.md §Perf).

    Tiles resident per step: x (N*3), out (N*K), plus the (N, N, K) RBF
    intermediate and (N, N) distance matrices the compiler keeps live.
    """
    f = 4  # f32
    return f * (
        n_atoms * 3
        + n_atoms * n_rbf
        + n_atoms * n_atoms * n_rbf
        + 2 * n_atoms * n_atoms
        + n_rbf
    )
