"""AOT path: manifest consistency + HLO text is parseable and well-formed.

These tests re-lower a small artifact in-process (fast) and validate the
manifest that `make artifacts` wrote, so a stale or hand-edited artifacts/
directory fails loudly before the rust side ever sees it.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_roundtrip_smoke():
    """Lower a tiny fn; the text must contain an ENTRY computation."""
    lowered = jax.jit(lambda a, b: (a @ b + 1.0,)).lower(
        jax.ShapeDtypeStruct((2, 2), jnp.float32),
        jax.ShapeDtypeStruct((2, 2), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "parameter(0)" in text


def test_toy_fwd_lowering_has_tuple_root():
    cfg = model.ToyConfig()
    lowered = jax.jit(lambda w, x: model.toy_fwd(w, x, cfg)).lower(
        jax.ShapeDtypeStruct((cfg.n_members * cfg.param_size,), jnp.float32),
        jax.ShapeDtypeStruct((4, cfg.n_in), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "tuple(" in text.lower()


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
class TestManifest:
    @pytest.fixture(autouse=True)
    def _load(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            self.manifest = json.load(f)
        self.by_name = {e["name"]: e for e in self.manifest["entries"]}

    def test_every_entry_file_exists(self):
        for e in self.manifest["entries"]:
            p = os.path.join(ART, e["file"])
            assert os.path.exists(p), e["name"]
            assert os.path.getsize(p) > 100

    def test_expected_entries_present(self):
        for name in ["potential_ground_fwd_b16", "potential_ground_train_t32",
                     "potential_ground_init", "potential_photo_fwd_b89",
                     "potential_dimer_fwd_b1", "surrogate_fwd_b8",
                     "toy_fwd_b20", "toy_train_t10", "toy_init"]:
            assert name in self.by_name, name

    def test_param_sizes_consistent(self):
        """meta.param_size must equal the config-derived size rust relies on."""
        cfgs = {"ground": aot.GROUND, "photo": aot.PHOTO, "dimer": aot.DIMER}
        for tag, cfg in cfgs.items():
            e = self.by_name[f"potential_{tag}_init"]
            assert e["meta"]["param_size"] == cfg.param_size
            assert e["meta"]["opt_size"] == cfg.opt_size
            assert e["outputs"][0]["shape"] == [cfg.n_members * cfg.param_size]

    def test_fwd_io_shapes(self):
        e = self.by_name["potential_ground_fwd_b16"]
        m = e["meta"]
        n3 = m["n_atoms"] * 3
        ins = {i["name"]: i["shape"] for i in e["inputs"]}
        outs = {o["name"]: o["shape"] for o in e["outputs"]}
        assert ins["w_all"] == [m["n_members"] * m["param_size"]]
        assert ins["x"] == [16, n3]
        assert outs["e_all"] == [m["n_members"], 16, m["n_states"]]
        assert outs["f_mean"] == [16, n3]

    def test_train_io_shapes(self):
        e = self.by_name["potential_ground_train_t32"]
        m = e["meta"]
        ins = {i["name"]: i["shape"] for i in e["inputs"]}
        outs = {o["name"]: o["shape"] for o in e["outputs"]}
        assert ins["w"] == [m["param_size"]]
        assert ins["opt"] == [m["opt_size"]]
        assert outs["w2"] == [m["param_size"]]
        assert outs["loss"] == [1]

    def test_hlo_text_entry_computation(self):
        for name in ["toy_fwd_b20", "potential_ground_fwd_b16"]:
            with open(os.path.join(ART, self.by_name[name]["file"])) as f:
                text = f.read()
            assert "ENTRY" in text
            # one parameter per manifest input
            for i, _inp in enumerate(self.by_name[name]["inputs"]):
                assert f"parameter({i})" in text

    def test_vmem_meta_recorded(self):
        e = self.by_name["potential_ground_euq_b16"]
        assert e["meta"]["vmem_committee_bytes"] > 0
        assert 0 < e["meta"]["mxu_utilization"] <= 1.0
