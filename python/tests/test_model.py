"""L2 correctness: potential/surrogate/toy committee models + train steps."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")

CFG = model.PotentialConfig(n_atoms=5, n_rbf=8, hidden=16, n_members=3,
                            n_states=2, n_globals=1)


def _batch(rng, b, cfg=CFG):
    x = jnp.asarray(rng.randn(b, cfg.n_atoms * 3) * 2.0, dtype=jnp.float32)
    g = jnp.asarray(rng.randn(b, cfg.n_globals), dtype=jnp.float32)
    s = jnp.zeros((b, cfg.n_states), jnp.float32).at[:, 0].set(1.0)
    return x, g, s


def test_param_size_matches_init():
    w = model.potential_init(jnp.uint32(0), CFG)
    assert w.shape == (CFG.n_members * CFG.param_size,)


def test_init_members_differ():
    w = model.members_view(model.potential_init(jnp.uint32(0), CFG),
                           CFG.n_members, CFG.param_size)
    assert float(jnp.max(jnp.abs(w[0] - w[1]))) > 1e-3
    assert float(jnp.max(jnp.abs(w[1] - w[2]))) > 1e-3


def test_init_deterministic_in_seed():
    a = model.potential_init(jnp.uint32(7), CFG)
    b = model.potential_init(jnp.uint32(7), CFG)
    c = model.potential_init(jnp.uint32(8), CFG)
    np.testing.assert_allclose(a, b)
    assert float(jnp.max(jnp.abs(a - c))) > 1e-4


def test_fwd_shapes():
    rng = np.random.RandomState(0)
    x, g, s = _batch(rng, 4)
    w = model.potential_init(jnp.uint32(0), CFG)
    e_all, e_mean, e_std, f_mean, f_std = model.potential_fwd(w, x, g, s, CFG)
    assert e_all.shape == (3, 4, 2)
    assert e_mean.shape == e_std.shape == (4, 2)
    assert f_mean.shape == f_std.shape == (4, 15)


def test_committee_stats_ddof1():
    y = jnp.asarray(np.random.RandomState(0).randn(4, 5, 2), jnp.float32)
    mean, std = model.committee_stats(y)
    np.testing.assert_allclose(mean, np.mean(np.asarray(y), axis=0), rtol=1e-5)
    np.testing.assert_allclose(std, np.std(np.asarray(y), axis=0, ddof=1),
                               rtol=1e-4, atol=1e-6)


def test_forces_are_negative_gradient():
    """f_mean == -d(mean state-weighted energy)/dx by finite differences."""
    rng = np.random.RandomState(1)
    x, g, s = _batch(rng, 2)
    w = model.potential_init(jnp.uint32(3), CFG)
    _, _, _, f_mean, _ = model.potential_fwd(w, x, g, s, CFG)

    def mean_e(xx):
        e_all, *_ = model.potential_fwd(w, xx, g, s, CFG)
        return float(jnp.mean(jnp.sum(e_all * s[None], axis=2), axis=0).sum())

    eps = 1e-3
    xn = np.asarray(x)
    for idx in [(0, 0), (1, 7), (0, 14)]:
        xp, xm = xn.copy(), xn.copy()
        xp[idx] += eps
        xm[idx] -= eps
        fd = (mean_e(jnp.asarray(xp)) - mean_e(jnp.asarray(xm))) / (2 * eps)
        assert abs(-fd - float(f_mean[idx])) < 5e-2 * max(1.0, abs(fd))


def test_euq_matches_fwd_energies():
    rng = np.random.RandomState(2)
    x, g, _ = _batch(rng, 3)
    w = model.potential_init(jnp.uint32(1), CFG)
    s = jnp.zeros((3, 2), jnp.float32).at[:, 0].set(1.0)
    e_fwd = model.potential_fwd(w, x, g, s, CFG)[0]
    e_euq = model.potential_euq(w, x, g, CFG)[0]
    np.testing.assert_allclose(e_fwd, e_euq, rtol=2e-5, atol=2e-5)


@given(seed=st.integers(0, 1000))
def test_train_step_descends(seed):
    """~30 Adam steps on a fixed batch must reduce the loss substantially."""
    rng = np.random.RandomState(seed)
    x, g, s = _batch(rng, 6)
    y_e = jnp.asarray(rng.randn(6, 2), jnp.float32)
    y_f = jnp.asarray(rng.randn(6, 15) * 0.1, jnp.float32)
    w = model.potential_init(jnp.uint32(seed), CFG)[:CFG.param_size]
    opt = jnp.zeros(CFG.opt_size, jnp.float32)
    first = None
    for i in range(30):
        w, opt, loss = model.potential_train_step(w, opt, x, g, s, y_e, y_f, CFG)
        if i == 0:
            first = float(loss[0])
    assert float(loss[0]) < first


def test_adam_step_count_advances():
    w = jnp.zeros(4, jnp.float32)
    opt = jnp.zeros(9, jnp.float32)
    gradv = jnp.ones(4, jnp.float32)
    _, opt1 = model.adam_step(w, opt, gradv, 1e-3)
    _, opt2 = model.adam_step(w, opt1, gradv, 1e-3)
    assert float(opt1[-1]) == 1.0 and float(opt2[-1]) == 2.0


# ---------------------------------------------------------------------------
# surrogate
# ---------------------------------------------------------------------------

SCFG = model.SurrogateConfig(grid=8, channels=4, dense=16, n_members=3)


def test_surrogate_shapes_and_stats():
    rng = np.random.RandomState(0)
    grid = jnp.asarray(rng.rand(5, 8, 8), jnp.float32)
    w = model.surrogate_init(jnp.uint32(0), SCFG)
    assert w.shape == (SCFG.n_members * SCFG.param_size,)
    y_all, y_mean, y_std = model.surrogate_fwd(w, grid, SCFG)
    assert y_all.shape == (3, 5, 2)
    np.testing.assert_allclose(y_mean, np.mean(np.asarray(y_all), 0), rtol=1e-4, atol=1e-5)
    assert float(jnp.min(y_std)) >= 0.0


def test_surrogate_train_descends():
    rng = np.random.RandomState(1)
    grid = jnp.asarray(rng.rand(6, 8, 8), jnp.float32)
    y = jnp.asarray(rng.randn(6, 2), jnp.float32)
    w = model.surrogate_init(jnp.uint32(1), SCFG)[:SCFG.param_size]
    opt = jnp.zeros(SCFG.opt_size, jnp.float32)
    losses = []
    for _ in range(40):
        w, opt, loss = model.surrogate_train_step(w, opt, grid, y, SCFG)
        losses.append(float(loss[0]))
    assert losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# toy
# ---------------------------------------------------------------------------

TCFG = model.ToyConfig()


def test_toy_learns_identity():
    """The SI toy setup: learn y = x (linear) to near-zero loss."""
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(32, 4), jnp.float32)
    w = model.toy_init(jnp.uint32(0), TCFG)[:TCFG.param_size]
    opt = jnp.zeros(TCFG.opt_size, jnp.float32)
    for _ in range(300):
        w, opt, loss = model.toy_train_step(w, opt, x, x, TCFG)
    assert float(loss[0]) < 5e-2


def test_toy_fwd_committee():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(7, 4), jnp.float32)
    w = model.toy_init(jnp.uint32(0), TCFG)
    y_all, y_mean, y_std = model.toy_fwd(w, x, TCFG)
    assert y_all.shape == (3, 7, 4)
    assert float(jnp.max(y_std)) > 0.0  # members differ
