"""L1 correctness: Pallas kernels vs pure-jnp references.

Hypothesis sweeps shapes; assert_allclose against ref.py is THE core
correctness signal for the kernels that end up inside the AOT artifacts.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.descriptor import descriptor, vmem_estimate_bytes
from compile.kernels.committee_mlp import (
    committee_mlp,
    mxu_utilization_estimate,
)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _coords(rng, b, n, spread=3.0):
    return jnp.asarray(rng.randn(b, n, 3) * spread, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# descriptor kernel
# ---------------------------------------------------------------------------


@given(b=st.integers(1, 6), n=st.integers(2, 10), k=st.integers(2, 24),
       seed=st.integers(0, 2**31 - 1))
def test_descriptor_matches_ref(b, n, k, seed):
    x = _coords(np.random.RandomState(seed), b, n)
    got = descriptor(x, k)
    want = ref.descriptor_ref(x, k)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_descriptor_permutation_equivariance():
    """Permuting atoms permutes the per-atom features identically."""
    rng = np.random.RandomState(0)
    x = _coords(rng, 2, 6)
    perm = np.array([3, 1, 5, 0, 2, 4])
    f = descriptor(x, 8)
    fp = descriptor(x[:, perm], 8)
    np.testing.assert_allclose(np.asarray(f)[:, perm], fp, rtol=1e-5, atol=1e-5)


def test_descriptor_translation_invariance():
    rng = np.random.RandomState(1)
    x = _coords(rng, 3, 5)
    shift = jnp.asarray(rng.randn(1, 1, 3), dtype=jnp.float32)
    np.testing.assert_allclose(
        descriptor(x, 8), descriptor(x + shift, 8), rtol=1e-4, atol=1e-4)


def test_descriptor_cutoff_zero_beyond_rc():
    """Two atoms farther apart than R_CUT contribute nothing."""
    x = jnp.array([[[0.0, 0.0, 0.0], [ref.R_CUT + 1.0, 0.0, 0.0]]],
                  dtype=jnp.float32)
    f = descriptor(x, 8)
    np.testing.assert_allclose(f, np.zeros_like(f), atol=1e-6)


def test_descriptor_grad_matches_ref_grad():
    """custom_vjp backward (reference transpose) == grad of the reference."""
    rng = np.random.RandomState(2)
    x = _coords(rng, 2, 4)

    def loss_k(xx):
        return jnp.sum(jnp.sin(descriptor(xx, 6)))

    def loss_r(xx):
        return jnp.sum(jnp.sin(ref.descriptor_ref(xx, 6)))

    gk = jax.grad(loss_k)(x)
    gr = jax.grad(loss_r)(x)
    np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-5)


def test_descriptor_grad_finite_difference():
    rng = np.random.RandomState(3)
    x = np.asarray(_coords(rng, 1, 3))

    def loss(xx):
        return float(jnp.sum(descriptor(jnp.asarray(xx, jnp.float32), 4)))

    g = np.asarray(jax.grad(
        lambda xx: jnp.sum(descriptor(xx, 4)))(jnp.asarray(x, jnp.float32)))
    eps = 1e-3
    for idx in [(0, 0, 0), (0, 1, 2), (0, 2, 1)]:
        xp, xm = x.copy(), x.copy()
        xp[idx] += eps
        xm[idx] -= eps
        fd = (loss(xp) - loss(xm)) / (2 * eps)
        assert abs(fd - g[idx]) < 5e-2 * max(1.0, abs(fd)), (idx, fd, g[idx])


def test_descriptor_vmem_estimate_positive_and_monotone():
    a = vmem_estimate_bytes(4, 8)
    b = vmem_estimate_bytes(8, 8)
    c = vmem_estimate_bytes(8, 16)
    assert 0 < a < b < c


# ---------------------------------------------------------------------------
# committee MLP kernel
# ---------------------------------------------------------------------------


def _mlp_weights(rng, m, d, h, s):
    mk = lambda *sh: jnp.asarray(rng.randn(*sh) * 0.3, dtype=jnp.float32)
    return (mk(m, d, h), mk(m, h), mk(m, h, h), mk(m, h), mk(m, h, s),
            mk(m, s))


@given(m=st.integers(1, 5), b=st.integers(1, 4), n=st.integers(1, 6),
       d=st.integers(1, 12), h=st.integers(1, 16), s=st.integers(1, 3),
       seed=st.integers(0, 2**31 - 1))
def test_committee_mlp_matches_ref(m, b, n, d, h, s, seed):
    rng = np.random.RandomState(seed)
    feats = jnp.asarray(rng.randn(b, n, d), dtype=jnp.float32)
    w = _mlp_weights(rng, m, d, h, s)
    got = committee_mlp(feats, *w)
    want = ref.committee_mlp_ref(feats, *w)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_committee_members_independent():
    """Changing member j's weights must not change member i's output."""
    rng = np.random.RandomState(0)
    feats = jnp.asarray(rng.randn(2, 3, 4), dtype=jnp.float32)
    w = list(_mlp_weights(rng, 3, 4, 8, 1))
    base = np.asarray(committee_mlp(feats, *w))
    w2 = [x.copy() for x in w]
    w2[0] = w2[0].at[2].set(w2[0][2] * 2.0 + 1.0)  # perturb member 2 only
    pert = np.asarray(committee_mlp(feats, *w2))
    np.testing.assert_allclose(base[:2], pert[:2], rtol=1e-6)
    assert np.abs(base[2] - pert[2]).max() > 1e-4


def test_mxu_estimate_bounds():
    assert 0.0 < mxu_utilization_estimate(89, 8, 17, 32) <= 1.0
    assert mxu_utilization_estimate(128, 1, 128, 128) == pytest.approx(1.0)
